//! Multi-worker training orchestration (paper §3.1, §6.1, §6.2).
//!
//! Workers are OS threads standing in for the paper's trainer processes —
//! one per GPU (or two, §6.1.5) in GPU mode, one per core group in CPU
//! mode. Each worker:
//!
//! 1. samples positives from its triplet assignment + joint negatives,
//! 2. gathers embeddings from the shared tables (billing the transfer
//!    ledger in GPU mode),
//! 3. runs the fwd/bwd step on its own compiled PJRT executable,
//! 4. applies relation gradients inline and hands entity gradients to its
//!    dedicated async updater (§3.5) — or applies inline in sync mode,
//! 5. crosses a barrier every `sync_interval` batches (§3.6), where the
//!    leader reshuffles the relation partition at epoch boundaries (§3.4).
//!
//! With `prefetch` on, steps 1–2 run on a dedicated helper thread one
//! batch ahead of compute (see [`super::prefetch`]): the worker receives
//! sampled+gathered buffers from a two-slot channel, patches any rows its
//! own updates dirtied since the gather, and bills the prefetched bytes
//! as overlapped rather than critical-path transfer.

use super::batch::{bytes_moved, split_grads, BatchBuffers, GatherVolume};
use super::device::{Hardware, TransferLedger};
use super::prefetch::Prefetcher;
use super::sync::SyncState;
use super::updater::AsyncUpdater;
use crate::kg::Dataset;
use crate::models::step::{StepGrads, StepShape};
use crate::models::{LossCfg, ModelKind};
use crate::obs::trace::{span, SpanId};
use crate::partition::partition_relations;
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::sampler::{Batch, NegativeConfig, NegativeSampler, PositiveSampler};
use crate::store::{split_cache_budget, CacheStats, EmbeddingStore, SparseAdagrad, StoreConfig};
use crate::util::timer::{PhaseTimes, Timer};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use anyhow::Result;
use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    /// artifact shape family ("default" / "tiny"); ignored for native
    /// when `shape` is set
    pub artifact_tag: String,
    /// explicit shape (required for the native backend)
    pub shape: Option<StepShape>,
    pub n_workers: usize,
    pub batches_per_worker: usize,
    pub lr: f32,
    pub init_scale: f32,
    /// fraction of negatives drawn in-batch ∝ degree (§3.3 / Table 4)
    pub neg_degree_frac: f64,
    /// overlap entity updates with next-batch compute (§3.5)
    pub async_update: bool,
    /// overlap next-batch sample+gather with compute (§3.5) via the
    /// prefetch pipeline
    pub prefetch: bool,
    /// buffers in flight when prefetching (clamped to >= 2 — classic
    /// double buffering); also the staleness bound in batches
    pub prefetch_depth: usize,
    /// bind relations to workers (§3.4); off = all workers sample all
    /// triplets and share all relations
    pub relation_partition: bool,
    /// barrier every this many batches (§3.6)
    pub sync_interval: usize,
    pub hardware: Hardware,
    pub seed: u64,
    /// record loss every this many batches (per worker 0)
    pub log_every: usize,
    /// score/grad kernel backend for the native step (bit-identical
    /// results either way; `Fused` is the cache-tiled fast path)
    pub kernels: crate::models::KernelBackend,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            n_workers: 1,
            batches_per_worker: 100,
            lr: 0.1,
            init_scale: 0.37,
            neg_degree_frac: 0.0,
            async_update: true,
            prefetch: false,
            prefetch_depth: 2,
            relation_partition: true,
            sync_interval: 1000,
            hardware: Hardware::Cpu,
            seed: 0,
            log_every: 50,
            kernels: crate::models::KernelBackend::Scalar,
        }
    }
}

/// Shared mutable training state (the "model"). The tables sit behind
/// [`EmbeddingStore`], so the same trainers run over dense, sharded, or
/// file-backed (mmap) storage — pick with [`ModelState::init_with_storage`].
pub struct ModelState {
    pub entities: Arc<dyn EmbeddingStore>,
    pub relations: Arc<dyn EmbeddingStore>,
    pub ent_opt: Arc<SparseAdagrad>,
    pub rel_opt: Arc<SparseAdagrad>,
    pub dim: usize,
    pub rel_dim: usize,
}

impl ModelState {
    pub fn init(dataset: &Dataset, model: ModelKind, dim: usize, cfg: &TrainConfig) -> Self {
        Self::init_with(dataset, model, dim, cfg.lr, cfg.init_scale, cfg.seed)
    }

    /// Initialize from bare hyperparameters on the default dense backend
    /// (used by the baseline trainers and tests).
    pub fn init_with(
        dataset: &Dataset,
        model: ModelKind,
        dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
    ) -> Self {
        Self::init_with_storage(dataset, model, dim, lr, init_scale, seed, &StoreConfig::dense())
            .expect("dense storage init cannot fail")
    }

    /// Initialize on an explicit storage backend. Row init is per-row
    /// seeded, so every backend yields byte-identical starting tables for
    /// the same seed; optimizer state is built on the same backend so it
    /// shards/spills alongside its table. For mmap storage with a cache
    /// budget (`storage.cache_mb`, defaulting to `storage.budget_mb`),
    /// every table — embeddings *and* AdaGrad state — gets a hot-row
    /// cache sized by its share of the total table bytes.
    #[allow(clippy::too_many_arguments)]
    pub fn init_with_storage(
        dataset: &Dataset,
        model: ModelKind,
        dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
        storage: &StoreConfig,
    ) -> Result<Self> {
        let storage = storage.resolved()?;
        let rel_dim = model.rel_dim(dim);
        let (n_ent, n_rel) = (dataset.n_entities(), dataset.n_relations());
        // proportional cache split: [entities, relations, ent_opt, rel_opt]
        let cache = match storage.cache_total_bytes() {
            Some(total) => {
                let tables = [
                    n_ent as u64 * dim as u64 * 4,
                    n_rel as u64 * rel_dim as u64 * 4,
                    n_ent as u64 * 4,
                    n_rel as u64 * 4,
                ];
                split_cache_budget(total, &tables).into_iter().map(Some).collect()
            }
            None => vec![None; 4],
        };
        Ok(ModelState {
            entities: storage.uniform_cached(
                "entities",
                n_ent,
                dim,
                init_scale,
                seed ^ 0xE,
                cache[0],
            )?,
            relations: storage.uniform_cached(
                "relations",
                n_rel,
                rel_dim,
                init_scale,
                seed ^ 0xF,
                cache[1],
            )?,
            ent_opt: Arc::new(SparseAdagrad::with_storage_cached(
                &storage,
                "entities.opt",
                n_ent,
                lr,
                cache[2],
            )?),
            rel_opt: Arc::new(SparseAdagrad::with_storage_cached(
                &storage,
                "relations.opt",
                n_rel,
                lr,
                cache[3],
            )?),
            dim,
            rel_dim,
        })
    }

    /// Placeholder state (zero tables, unit optimizers) for runs whose
    /// real parameters live elsewhere — distributed KVStore shards
    /// initialize and train server-side, and are dumped into this state
    /// afterwards. Skips the (large) random init.
    pub fn placeholder(dataset: &Dataset, model: ModelKind, dim: usize, lr: f32) -> Self {
        let rel_dim = model.rel_dim(dim);
        ModelState {
            entities: Arc::new(crate::store::DenseStore::zeros(dataset.n_entities(), dim)),
            relations: Arc::new(crate::store::DenseStore::zeros(dataset.n_relations(), rel_dim)),
            ent_opt: Arc::new(SparseAdagrad::new(1, lr)),
            rel_opt: Arc::new(SparseAdagrad::new(1, lr)),
            dim,
            rel_dim,
        }
    }

    pub fn n_params(&self) -> usize {
        self.entities.n_params() + self.relations.n_params()
    }

    /// Summed hot-row-cache counters across the embedding tables and
    /// their optimizer state (zero when nothing is cached). Cumulative;
    /// `run_training` reports the per-run delta.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in [
            self.entities.cache_stats(),
            self.relations.cache_stats(),
            self.ent_opt.cache_stats(),
            self.rel_opt.cache_stats(),
        ]
        .into_iter()
        .flatten()
        {
            total.accumulate(s);
        }
        total
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub wall_secs: f64,
    /// wall + critical-path simulated transfer time (GPU mode)
    pub sim_secs: f64,
    /// simulated *parallel* wall-clock: max per-worker thread-CPU busy
    /// time + critical transfer. On this 1-core testbed concurrent
    /// threads time-share, so this — not `wall_secs` — is the multi-worker
    /// quantity comparable to the paper's multi-GPU/multi-core wall times
    /// (see DESIGN.md §Hardware-Adaptation).
    pub sim_parallel_secs: f64,
    /// per-worker thread-CPU busy seconds
    pub worker_busy_secs: Vec<f64>,
    pub total_batches: u64,
    /// throughput under the simulated-parallel clock
    pub triplets_per_sec: f64,
    pub mean_loss_tail: f32,
    pub loss_curve: Vec<(u64, f32)>,
    pub phases: Vec<(String, f64)>,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub overlapped_bytes: u64,
    /// hot-row-cache counters over this run (all zero when uncached)
    pub cache: CacheStats,
}

struct WorkerOut {
    phases: PhaseTimes,
    losses: Vec<(u64, f32)>,
    batches: u64,
    busy_secs: f64,
}

/// Triplet assignment for worker `w` under the current strategy/epoch.
fn assignment(
    dataset: &Dataset,
    cfg: &TrainConfig,
    sync: &SyncState,
    w: usize,
) -> Vec<u32> {
    if cfg.relation_partition && cfg.n_workers > 1 {
        let part = sync.partition().expect("relation partition missing");
        part.triplets_of(w as u32).into_iter().map(|i| i as u32).collect()
    } else {
        // strided split — balanced and disjoint
        (0..dataset.train.len() as u32)
            .filter(|i| (*i as usize) % cfg.n_workers == w)
            .collect()
    }
}

/// Run a full training job; returns aggregate stats. The embeddings are
/// left trained inside `state`.
pub fn run_training(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &TrainConfig,
) -> Result<TrainStats> {
    assert!(cfg.n_workers >= 1);
    let initial_part = (cfg.relation_partition && cfg.n_workers > 1)
        .then(|| partition_relations(&dataset.train, cfg.n_workers, cfg.seed));
    let sync = SyncState::new(cfg.n_workers, initial_part);
    let ledger = TransferLedger::new();
    let cache_before = state.cache_stats();

    let timer = Timer::new();
    let outs: Vec<Result<WorkerOut>> = crate::util::threadpool::scoped_map(cfg.n_workers, |w| {
        worker_loop(dataset, state, manifest, cfg, &sync, &ledger, w)
    });
    let wall = timer.elapsed_secs();

    let mut phases = PhaseTimes::new();
    let mut losses = Vec::new();
    let mut batches = 0u64;
    let mut worker_busy = Vec::with_capacity(cfg.n_workers);
    for out in outs {
        let out = out?;
        phases.merge(&out.phases);
        batches += out.batches;
        worker_busy.push(out.busy_secs);
        if out.losses.len() > losses.len() {
            losses = out.losses;
        }
    }
    let b = cfg
        .shape
        .map(|s| s.batch)
        .or_else(|| {
            manifest.and_then(|m| {
                m.find_train(cfg.model.name(), loss_name(&cfg.loss), &cfg.artifact_tag)
                    .ok()
                    .map(|a| a.batch)
            })
        })
        .unwrap_or(0);
    let transfer = ledger.critical_secs(cfg.hardware, cfg.n_workers);
    let sim = wall + transfer;
    let max_busy = worker_busy.iter().cloned().fold(0f64, f64::max);
    let sim_parallel = max_busy + transfer;
    let tail = losses.iter().rev().take(10).map(|&(_, l)| l).collect::<Vec<_>>();
    Ok(TrainStats {
        wall_secs: wall,
        sim_secs: sim,
        sim_parallel_secs: sim_parallel,
        worker_busy_secs: worker_busy,
        total_batches: batches,
        triplets_per_sec: (batches * b as u64) as f64 / sim_parallel.max(1e-9),
        mean_loss_tail: if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        },
        loss_curve: losses,
        phases: phases
            .entries()
            .iter()
            .map(|&(p, d)| (p.to_string(), d.as_secs_f64()))
            .collect(),
        h2d_bytes: ledger.h2d.get(),
        d2h_bytes: ledger.d2h.get(),
        overlapped_bytes: ledger.overlapped.get(),
        cache: state.cache_stats().since(&cache_before),
    })
}

fn loss_name(l: &LossCfg) -> &'static str {
    match l.kind {
        crate::models::LossKind::Logistic => "logistic",
        crate::models::LossKind::Margin(_) => "margin",
    }
}

/// Per-worker state shared by the sequential and pipelined loop bodies:
/// compute backend, update application, transfer billing, and the sync
/// barrier. The two loops differ only in how a sampled+gathered batch
/// arrives — drawn inline, or received from the prefetch thread.
struct WorkerCtx<'a> {
    dataset: &'a Dataset,
    state: &'a ModelState,
    cfg: &'a TrainConfig,
    sync: &'a SyncState,
    ledger: &'a TransferLedger,
    w: usize,
    backend: TrainBackend,
    shape: StepShape,
    rel_dim: usize,
    updater: Option<AsyncUpdater>,
    gpu: bool,
    phases: PhaseTimes,
    losses: Vec<(u64, f32)>,
    last_epoch: u64,
}

impl WorkerCtx<'_> {
    /// Bill a full-batch gather to the transfer ledger. Entity rows move
    /// host→device every batch; relation rows only when relation
    /// partitioning is off (§3.4 pins them on-GPU). A sequential gather
    /// sits on the critical path (h2d) — except its hot-row-cache hits,
    /// which never leave memory and are credited as overlapped/zero-cost
    /// alongside the moved bytes; a prefetched gather overlaps the
    /// previous batch's compute, so all its bytes are credited as
    /// overlapped (§3.5).
    fn bill_gather(&mut self, batch: &Batch, vol: GatherVolume, overlapped: bool) {
        if !self.gpu {
            return;
        }
        let rel_values = (batch.rels.len() * self.rel_dim) as u64;
        let ent_values = vol.values - rel_values;
        if overlapped {
            self.ledger.add_overlapped(bytes_moved(ent_values));
            if !self.cfg.relation_partition {
                self.ledger.add_overlapped(bytes_moved(rel_values));
            }
        } else {
            self.ledger.add_h2d(bytes_moved(ent_values - vol.ent_hit_values));
            self.ledger.add_overlapped(bytes_moved(vol.ent_hit_values));
            if !self.cfg.relation_partition {
                self.ledger.add_h2d(bytes_moved(rel_values - vol.rel_hit_values));
                self.ledger.add_overlapped(bytes_moved(vol.rel_hit_values));
            }
        }
    }

    /// (3) fwd/bwd step + loss logging.
    fn compute(&mut self, step: u64, buf: &BatchBuffers) -> Result<StepGrads> {
        let _span = span(SpanId::Compute);
        let backend = &self.backend;
        let grads = self.phases.time("compute", || backend.step(&buf.inputs()))?;
        if step % self.cfg.log_every as u64 == 0 {
            self.losses.push((step, grads.loss));
        }
        Ok(grads)
    }

    /// (4) apply the update. Returns the unique (entity, relation) ids
    /// written *inline* on this thread — what the pipelined loop must
    /// patch in prefetched buffers. Entity ids are empty under async
    /// updates (those land on the updater thread; Hogwild staleness).
    fn update(&mut self, batch: &Batch, grads: &StepGrads) -> (Vec<u64>, Vec<u64>) {
        let _span = span(SpanId::Update);
        let (state, cfg, ledger, updater) = (self.state, self.cfg, self.ledger, &self.updater);
        let (gpu, dim, rel_dim) = (self.gpu, self.shape.dim, self.rel_dim);
        self.phases.time("update", || {
            let (ent_g, mut rel_g) = split_grads(batch, grads, dim, rel_dim);
            if gpu && !cfg.relation_partition {
                ledger.add_d2h(bytes_moved(rel_g.rows.len() as u64));
            }
            // split_grads pre-accumulated duplicates → unique fast path
            state.rel_opt.apply_unique(&state.relations, &rel_g.ids, &rel_g.rows);
            let rel_ids = std::mem::take(&mut rel_g.ids);
            let ent_bytes = bytes_moved(ent_g.rows.len() as u64);
            match updater {
                Some(up) => {
                    if gpu {
                        ledger.add_overlapped(ent_bytes);
                    }
                    up.submit(ent_g);
                    (Vec::new(), rel_ids)
                }
                None => {
                    if gpu {
                        ledger.add_d2h(ent_bytes);
                    }
                    state.ent_opt.apply_unique(&state.entities, &ent_g.ids, &ent_g.rows);
                    (ent_g.ids, rel_ids)
                }
            }
        })
    }

    /// (5) periodic synchronization. `reset` installs a recomputed triplet
    /// assignment — directly into the sampler (sequential) or through the
    /// prefetcher's control channel (pipelined).
    fn sync_barrier(&mut self, step: u64, reset: &mut dyn FnMut(Vec<u32>)) {
        if self.cfg.n_workers <= 1 || (step + 1) % self.cfg.sync_interval as u64 != 0 {
            return;
        }
        let _span = span(SpanId::SyncBarrier);
        let (dataset, cfg, sync, w) = (self.dataset, self.cfg, self.sync, self.w);
        let (updater, last_epoch) = (&self.updater, self.last_epoch);
        self.phases.time("sync", || {
            if let Some(up) = updater {
                up.flush();
            }
            let leader = sync.wait();
            // epoch-boundary relation reshuffle (§3.4)
            if cfg.relation_partition {
                if leader && last_epoch > sync.partition_epoch() {
                    sync.install_partition(
                        partition_relations(&dataset.train, cfg.n_workers, cfg.seed ^ last_epoch),
                        last_epoch,
                    );
                }
                sync.wait();
                if sync.partition_epoch() == last_epoch && last_epoch > 0 {
                    reset(assignment(dataset, cfg, sync, w));
                }
            }
        });
    }
}

/// The classic sequential loop: sample → gather → compute → update, all
/// on the worker thread.
fn run_sequential(
    ctx: &mut WorkerCtx<'_>,
    mut pos: PositiveSampler,
    mut neg: NegativeSampler,
) -> Result<()> {
    let mut buf = BatchBuffers::new(&ctx.shape, ctx.rel_dim);
    let mut idx_buf: Vec<u32> = Vec::with_capacity(ctx.shape.batch);
    let mut epoch_span = span(SpanId::TrainEpoch);
    let mut epoch_mark = ctx.last_epoch;
    for step in 0..ctx.cfg.batches_per_worker as u64 {
        if ctx.last_epoch != epoch_mark {
            // close the previous epoch's span before opening the next —
            // assignment alone would nest them backwards
            drop(epoch_span);
            epoch_span = span(SpanId::TrainEpoch);
            epoch_mark = ctx.last_epoch;
        }
        let _batch_span = span(SpanId::TrainBatch);

        // (1) sample
        let (shape, dataset) = (ctx.shape, ctx.dataset);
        let (crossed, batch) = {
            let _s = span(SpanId::Sample);
            let crossed = ctx.phases.time("sample", || pos.next_batch(shape.batch, &mut idx_buf));
            let batch = ctx.phases.time("sample", || neg.assemble(&dataset.train, &idx_buf));
            (crossed, batch)
        };
        if crossed {
            ctx.last_epoch = pos.epoch();
        }

        // (2) gather
        let state = ctx.state;
        let vol = {
            let _s = span(SpanId::Gather);
            ctx.phases.time("gather", || buf.gather(&batch, &*state.entities, &*state.relations))
        };
        ctx.bill_gather(&batch, vol, false);

        // (3) compute + (4) update + (5) sync
        let grads = ctx.compute(step, &buf)?;
        ctx.update(&batch, &grads);
        ctx.sync_barrier(step, &mut |indices| pos.reset_indices(indices));
    }
    drop(epoch_span);
    Ok(())
}

/// Unique ids one update step wrote inline — the pipelined loop keeps a
/// short window of these so it can repair prefetched buffers that were
/// gathered before the step landed.
struct WrittenIds {
    step: u64,
    ents: HashSet<u64>,
    rels: HashSet<u64>,
}

/// The two-stage pipeline (§3.5): a prefetch thread runs sample(N+1) +
/// gather(N+1) while this thread computes step N. The worker's only
/// gather-path work is patching rows its own updates dirtied after the
/// prefetched gather's stamp — which restores exact sequential semantics
/// under synchronous updates (see [`super::prefetch`] module docs).
fn run_pipelined<'a>(
    ctx: &mut WorkerCtx<'a>,
    pos: PositiveSampler,
    neg: NegativeSampler,
) -> Result<()> {
    let depth = ctx.cfg.prefetch_depth.max(2);
    // lint:allow(metrics-registry) — applied stamp (Release/Acquire), not a stat
    let applied = Arc::new(AtomicU64::new(0));
    let dataset: &'a Dataset = ctx.dataset;
    let (entities, relations) = (ctx.state.entities.clone(), ctx.state.relations.clone());
    let (shape, rel_dim) = (ctx.shape, ctx.rel_dim);
    std::thread::scope(|s| -> Result<()> {
        let mut pf = Prefetcher::spawn_scoped(
            s,
            pos,
            neg,
            &dataset.train,
            entities,
            relations,
            shape,
            rel_dim,
            depth,
            applied.clone(),
        )?;
        // ids written inline per recent step, newest at the back; sized
        // so it always covers every update a live stamp can predate
        let mut written: VecDeque<WrittenIds> = VecDeque::new();
        // dirty-id scratch, reused across steps (hot loop: no allocation)
        let mut ent_dirty: HashSet<u64> = HashSet::new();
        let mut rel_dirty: HashSet<u64> = HashSet::new();
        let patched = crate::obs::metrics::global().counter("train.prefetch.patched_values");
        let mut epoch_span = span(SpanId::TrainEpoch);
        let mut epoch_mark = ctx.last_epoch;
        for step in 0..ctx.cfg.batches_per_worker as u64 {
            if ctx.last_epoch != epoch_mark {
                drop(epoch_span);
                epoch_span = span(SpanId::TrainEpoch);
                epoch_mark = ctx.last_epoch;
            }
            let _batch_span = span(SpanId::TrainBatch);

            // (1)+(2) arrive prefetched; blocking here is the pipeline stall
            let mut pb = ctx.phases.time("prefetch", || pf.recv())?;
            // track the sampler epoch by value, not by the crossed flag: a
            // crossing carried by a batch discarded during a generation
            // reset must still advance last_epoch, or this worker skips a
            // reshuffle its peers perform
            ctx.last_epoch = ctx.last_epoch.max(pb.epoch);
            ctx.bill_gather(&pb.batch, pb.moved, true);

            // (2b) patch rows written since the gather's stamp
            debug_assert!(
                match written.front() {
                    Some(wr) => wr.step <= pb.gathered_at,
                    None => true,
                },
                "patch window no longer covers stamp {}",
                pb.gathered_at
            );
            ent_dirty.clear();
            rel_dirty.clear();
            for wr in &written {
                if wr.step >= pb.gathered_at {
                    ent_dirty.extend(wr.ents.iter().copied());
                    rel_dirty.extend(wr.rels.iter().copied());
                }
            }
            let state = ctx.state;
            let (ent_patched, rel_patched) = {
                let _s = span(SpanId::PrefetchPatch);
                ctx.phases.time("gather", || {
                    let (ents, rels) = (&*state.entities, &*state.relations);
                    pb.buf.patch_rows(&pb.batch, ents, rels, &ent_dirty, &rel_dirty)
                })
            };
            patched.add(ent_patched + rel_patched);
            if ctx.gpu {
                // re-gathered rows are on the critical path, unlike the
                // prefetched bulk; relation rows stay pinned on-GPU under
                // §3.4 partitioning and never cross the link (mirroring
                // bill_gather)
                ctx.ledger.add_h2d(bytes_moved(ent_patched));
                if !ctx.cfg.relation_partition {
                    ctx.ledger.add_h2d(bytes_moved(rel_patched));
                }
            }

            // (3) compute + (4) update
            let grads = ctx.compute(step, &pb.buf)?;
            let (ent_ids, rel_ids) = ctx.update(&pb.batch, &grads);
            applied.store(step + 1, Ordering::Release);
            written.push_back(WrittenIds {
                step,
                ents: ent_ids.into_iter().collect(),
                rels: rel_ids.into_iter().collect(),
            });
            if written.len() > depth + 2 {
                written.pop_front();
            }
            pf.recycle(pb);

            // (5) sync; a reshuffle restarts the prefetch stream
            ctx.sync_barrier(step, &mut |indices| pf.reset_indices(indices));
        }
        drop(epoch_span);
        // fold the helper thread's (overlapped) sample/gather time into
        // this worker's phase report
        ctx.phases.merge(&pf.finish()?);
        Ok(())
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &TrainConfig,
    sync: &SyncState,
    ledger: &TransferLedger,
    w: usize,
) -> Result<WorkerOut> {
    // backend is created inside the worker thread (PJRT client is !Send)
    let backend = TrainBackend::create_with_kernels(
        cfg.backend,
        cfg.model,
        cfg.loss,
        manifest,
        &cfg.artifact_tag,
        cfg.shape,
        cfg.kernels,
    )?;
    let shape = backend.shape();
    let rel_dim = backend.rel_dim();
    anyhow::ensure!(
        shape.dim == state.dim && rel_dim == state.rel_dim,
        "artifact dims ({}, {}) do not match model state ({}, {})",
        shape.dim,
        rel_dim,
        state.dim,
        state.rel_dim
    );

    let pos = PositiveSampler::over_indices(
        assignment(dataset, cfg, sync, w),
        cfg.seed ^ (w as u64 + 1),
    );
    let neg = NegativeSampler::new(
        NegativeConfig {
            k: shape.neg_k,
            chunk_size: shape.chunk_size(),
            degree_frac: cfg.neg_degree_frac,
            local_pool: None,
        },
        dataset.n_entities(),
        cfg.seed ^ (0x9e00 + w as u64),
    );
    let updater = cfg
        .async_update
        .then(|| AsyncUpdater::spawn(state.entities.clone(), state.ent_opt.clone(), 4));

    let cpu_timer = crate::util::cputime::CpuTimer::new();
    let mut ctx = WorkerCtx {
        dataset,
        state,
        cfg,
        sync,
        ledger,
        w,
        backend,
        shape,
        rel_dim,
        updater,
        gpu: cfg.hardware.is_gpu(),
        phases: PhaseTimes::new(),
        losses: Vec::new(),
        last_epoch: 0,
    };
    if cfg.prefetch {
        run_pipelined(&mut ctx, pos, neg)?;
    } else {
        run_sequential(&mut ctx, pos, neg)?;
    }

    let busy_secs = cpu_timer.elapsed().as_secs_f64();
    if let Some(up) = ctx.updater.take() {
        up.flush();
        up.join();
    }
    Ok(WorkerOut {
        phases: ctx.phases,
        losses: ctx.losses,
        batches: cfg.batches_per_worker as u64,
        busy_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(n_workers: usize) -> TrainConfig {
        TrainConfig {
            backend: BackendKind::Native,
            shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 16, dim: 16 }),
            n_workers,
            batches_per_worker: 30,
            sync_interval: 10,
            log_every: 5,
            ..Default::default()
        }
    }

    #[test]
    fn single_worker_loss_decreases() {
        let dataset = Dataset::load("tiny", 1).unwrap();
        let cfg = tiny_cfg(1);
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert_eq!(stats.total_batches, 30);
        let first = stats.loss_curve.first().unwrap().1;
        let last = stats.loss_curve.last().unwrap().1;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn multi_worker_runs_and_trains() {
        let dataset = Dataset::load("tiny", 2).unwrap();
        let mut cfg = tiny_cfg(4);
        cfg.batches_per_worker = 40;
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert_eq!(stats.total_batches, 160);
        assert!(stats.mean_loss_tail < stats.loss_curve.first().unwrap().1);
    }

    #[test]
    fn gpu_mode_ledgers_transfers() {
        let dataset = Dataset::load("tiny", 3).unwrap();
        let mut cfg = tiny_cfg(2);
        cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
        cfg.relation_partition = false;
        cfg.async_update = false;
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert!(stats.h2d_bytes > 0);
        assert!(stats.d2h_bytes > 0);
        assert!(stats.sim_secs > stats.wall_secs);
    }

    #[test]
    fn relation_partition_reduces_rel_traffic() {
        let dataset = Dataset::load("tiny", 4).unwrap();
        let mk = |rel_part: bool| {
            let mut cfg = tiny_cfg(2);
            cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
            cfg.relation_partition = rel_part;
            cfg.async_update = false;
            let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
            run_training(&dataset, &state, None, &cfg).unwrap()
        };
        let with = mk(true);
        let without = mk(false);
        assert!(
            with.h2d_bytes < without.h2d_bytes,
            "rel_part should cut h2d: {} vs {}",
            with.h2d_bytes,
            without.h2d_bytes
        );
    }

    #[test]
    fn async_overlap_moves_bytes_off_critical_path() {
        let dataset = Dataset::load("tiny", 5).unwrap();
        let mk = |async_update: bool, prefetch: bool| {
            let mut cfg = tiny_cfg(1);
            cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
            cfg.async_update = async_update;
            cfg.prefetch = prefetch;
            let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
            run_training(&dataset, &state, None, &cfg).unwrap()
        };
        let a = mk(true, false);
        let s = mk(false, false);
        assert!(a.overlapped_bytes > 0);
        assert_eq!(s.overlapped_bytes, 0);
        assert!(a.d2h_bytes < s.d2h_bytes);
        // the prefetch pipeline overlaps the gather h2d traffic on top of
        // the async updater's d2h overlap: both knobs on credits strictly
        // more overlapped bytes than either alone, and takes gather bytes
        // off the critical path
        let p = mk(false, true);
        let ap = mk(true, true);
        assert!(p.overlapped_bytes > 0, "prefetched gathers must be credited");
        assert!(p.h2d_bytes < s.h2d_bytes, "{} vs {}", p.h2d_bytes, s.h2d_bytes);
        assert!(ap.overlapped_bytes > a.overlapped_bytes);
        assert!(ap.overlapped_bytes > p.overlapped_bytes);
    }

    #[test]
    fn prefetch_pipeline_is_byte_identical_single_worker() {
        // sync updates + 1 worker: the pipeline's patch protocol must
        // reproduce the sequential loop bit for bit
        let dataset = Dataset::load("tiny", 6).unwrap();
        let mk = |prefetch: bool| {
            let mut cfg = tiny_cfg(1);
            cfg.async_update = false;
            cfg.prefetch = prefetch;
            cfg.batches_per_worker = 50;
            let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
            let stats = run_training(&dataset, &state, None, &cfg).unwrap();
            (stats.loss_curve, state.entities.snapshot(), state.relations.snapshot())
        };
        let (curve_off, ents_off, rels_off) = mk(false);
        let (curve_on, ents_on, rels_on) = mk(true);
        assert_eq!(curve_on, curve_off, "loss trajectory changed by prefetch");
        assert_eq!(ents_on, ents_off, "entity table changed by prefetch");
        assert_eq!(rels_on, rels_off, "relation table changed by prefetch");
    }

    #[test]
    fn prefetch_multiworker_reshuffles_and_trains() {
        // several epochs across barriers: exercises the prefetcher's
        // generation reset on relation-partition reshuffle
        let dataset = Dataset::load("tiny", 7).unwrap();
        let mut cfg = tiny_cfg(2);
        cfg.prefetch = true;
        cfg.batches_per_worker = 60;
        cfg.sync_interval = 10;
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert_eq!(stats.total_batches, 120);
        assert!(stats.mean_loss_tail < stats.loss_curve.first().unwrap().1);
    }
}
