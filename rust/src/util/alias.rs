//! Walker alias method for O(1) sampling from a discrete distribution.
//!
//! Used for degree-proportional entity sampling (paper §3.3 / §5.3
//! protocol 2) and for the Zipf relation-frequency generator.

use super::rng::Rng;

/// Alias table over `n` outcomes with arbitrary non-negative weights.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from weights. Zero-weight outcomes are never sampled.
    /// Panics if all weights are zero or the table is empty.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "empty alias table");
        assert!(n < u32::MAX as usize, "alias table too large");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let scale = n as f64 / total;

        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * scale).collect();

        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = *large.last().unwrap();
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers are 1.0 up to float error.
        for i in large.into_iter().chain(small) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.gen_index(self.prob.len());
        if rng.gen_f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(weights: &[f64], draws: usize) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Rng::seed_from_u64(42);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let freq = empirical(&[1.0; 10], 200_000);
        for f in freq {
            assert!((f - 0.1).abs() < 0.01, "f={f}");
        }
    }

    #[test]
    fn skewed_weights() {
        let w = [8.0, 4.0, 2.0, 1.0, 1.0];
        let total: f64 = w.iter().sum();
        let freq = empirical(&w, 400_000);
        for (f, wi) in freq.iter().zip(&w) {
            let expect = wi / total;
            assert!((f - expect).abs() < 0.01, "f={f} expect={expect}");
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let freq = empirical(&[1.0, 0.0, 1.0], 100_000);
        assert_eq!(freq[1], 0.0);
    }

    #[test]
    fn single_outcome() {
        let freq = empirical(&[3.5], 100);
        assert_eq!(freq[0], 1.0);
    }

    #[test]
    #[should_panic]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
