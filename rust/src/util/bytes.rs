//! Byte-level encode/decode helpers for the KVStore wire protocol and the
//! on-disk dataset caches. Little-endian throughout; no serde in the
//! vendored dep set, so framing is explicit and versioned at the protocol
//! layer (`kvstore/protocol.rs`).

/// Incrementally encode values into a growable buffer.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64_slice(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x);
        }
    }

    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

#[derive(Debug)]
pub struct DecodeError(pub &'static str, pub usize);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decode error: {} at offset {}", self.0, self.1)
    }
}

impl std::error::Error for DecodeError {}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeError("truncated", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64_vec(&mut self) -> Result<Vec<u64>, DecodeError> {
        let n = self.u64()? as usize;
        if n > self.remaining() / 8 {
            return Err(DecodeError("length overflow", self.pos));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>, DecodeError> {
        let n = self.u64()? as usize;
        if n > self.remaining() / 4 {
            return Err(DecodeError("length overflow", self.pos));
        }
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }

    pub fn str(&mut self) -> Result<String, DecodeError> {
        let n = self.u64()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| DecodeError("utf8", self.pos))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Reinterpret an f32 slice as bytes (for bulk I/O of embedding rows).
///
/// This and [`f32_as_bytes_mut`] are the repo's *only* sanctioned
/// slice-reinterpret sites — every bulk f32↔byte view (mmap row I/O,
/// checkpoint load, PJRT literal upload) routes through them so the
/// soundness argument is audited once (see `unsafe-budget.toml`).
/// Byte order is the host's; all on-disk/wire users are little-endian
/// by protocol contract.
pub fn f32_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: `f32` is a plain-old-data type with no padding or invalid
    // bit patterns, so any f32 is 4 valid bytes. The output pointer and
    // length cover exactly the input slice (align 4 → align 1 is always
    // valid; `len * 4` cannot overflow because the slice already occupies
    // `len * 4` addressable bytes). Lifetime and aliasing mirror the
    // input `&[f32]` borrow.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Mutable byte view of an f32 slice (decode straight into a reused f32
/// buffer: mmap `read_row`, checkpoint load). Same audited contract as
/// [`f32_as_bytes`].
pub fn f32_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: as in `f32_as_bytes`, plus: every byte pattern is a valid
    // f32 bit pattern, so arbitrary writes through the byte view leave
    // the f32 slice initialized and valid. The unique `&mut` borrow of
    // the input is threaded through to the output, so no aliasing.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 4) }
}

/// Copy bytes into an f32 vec (len must be a multiple of 4).
pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    assert_eq!(b.len() % 4, 0);
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(123456);
        w.u64(u64::MAX - 3);
        w.f32(-1.5);
        w.str("hello");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 123456);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_vecs() {
        let mut w = Writer::new();
        w.u64_slice(&[1, 2, 3]);
        w.f32_slice(&[0.5, -0.5]);
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f32_vec().unwrap(), vec![0.5, -0.5]);
    }

    #[test]
    fn truncated_fails() {
        let mut w = Writer::new();
        w.u64(9);
        let mut r = Reader::new(&w.buf[..4]);
        assert!(r.u64().is_err());
    }

    #[test]
    fn hostile_length_fails() {
        // A declared length far beyond the actual payload must not OOM.
        let mut w = Writer::new();
        w.u64(u64::MAX / 2);
        let mut r = Reader::new(&w.buf);
        assert!(r.f32_vec().is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.0f32, -2.5, 3.25];
        assert_eq!(bytes_to_f32(f32_as_bytes(&v)), v);
    }

    #[test]
    fn f32_bytes_mut_writes_through() {
        let src = [1.0f32, -2.5, 3.25];
        let mut dst = vec![0f32; 3];
        f32_as_bytes_mut(&mut dst).copy_from_slice(f32_as_bytes(&src));
        assert_eq!(dst, src);
        assert_eq!(f32_as_bytes_mut(&mut dst).len(), 12);
    }
}
