//! Per-thread CPU time (CLOCK_THREAD_CPUTIME_ID).
//!
//! The evaluation testbed has a single CPU core, so wall-clock timing of
//! concurrent trainer threads measures time-sharing, not parallel
//! behaviour. Thread CPU time is preemption-immune: a worker's busy time
//! is what it *would* take on its own core. The scaling benches (Fig 5/6)
//! reconstruct parallel wall-clock as `max_w busy_w + sync + transfer`
//! from these measurements — documented in DESIGN.md and EXPERIMENTS.md.

use std::time::Duration;

/// CPU time consumed by the calling thread.
pub fn thread_cpu_time() -> Duration {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime failed");
    Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Stopwatch over thread CPU time.
pub struct CpuTimer {
    start: Duration,
}

impl Default for CpuTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl CpuTimer {
    pub fn new() -> Self {
        CpuTimer { start: thread_cpu_time() }
    }

    pub fn elapsed(&self) -> Duration {
        thread_cpu_time().saturating_sub(self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_work_accumulates_cpu_time() {
        let t = CpuTimer::new();
        let mut x = 0u64;
        for i in 0..20_000_000u64 {
            x = x.wrapping_add(i).rotate_left(7);
        }
        std::hint::black_box(x);
        assert!(t.elapsed() > Duration::from_millis(1));
    }

    #[test]
    fn sleep_does_not_count() {
        let t = CpuTimer::new();
        std::thread::sleep(Duration::from_millis(50));
        assert!(t.elapsed() < Duration::from_millis(20));
    }
}
