//! Per-thread CPU time (CLOCK_THREAD_CPUTIME_ID).
//!
//! The evaluation testbed has a single CPU core, so wall-clock timing of
//! concurrent trainer threads measures time-sharing, not parallel
//! behaviour. Thread CPU time is preemption-immune: a worker's busy time
//! is what it *would* take on its own core. The scaling benches (Fig 5/6)
//! reconstruct parallel wall-clock as `max_w busy_w + sync + transfer`
//! from these measurements — documented in DESIGN.md and EXPERIMENTS.md.

use std::time::Duration;

#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
mod imp {
    use std::os::raw::{c_int, c_long};
    use std::time::Duration;

    // Layout of struct timespec on 64-bit Linux (time_t == c_long == i64).
    #[repr(C)]
    struct Timespec {
        tv_sec: c_long,
        tv_nsec: c_long,
    }

    // Direct libc symbol (no `libc` crate in the vendored dep set); the C
    // library is linked by default. The clockid value is Linux-specific,
    // which is why this path is gated on target_os = "linux".
    extern "C" {
        fn clock_gettime(clk_id: c_int, tp: *mut Timespec) -> c_int;
    }

    const CLOCK_THREAD_CPUTIME_ID: c_int = 3;

    pub fn thread_cpu_time() -> Duration {
        let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
        // SAFETY: clock_gettime is given a valid clock id and a pointer to
        // a live, correctly-laid-out (#[repr(C)], 64-bit Linux) Timespec;
        // it writes at most size_of::<Timespec>() bytes and keeps no alias.
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0, "clock_gettime failed");
        Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
    }
}

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
mod imp {
    use std::time::{Duration, Instant};

    // Fallback: wall-clock since first use on this thread (over-counts
    // under time-sharing, but keeps the crate building everywhere).
    thread_local! {
        static START: Instant = Instant::now();
    }

    pub fn thread_cpu_time() -> Duration {
        START.with(|s| s.elapsed())
    }
}

/// CPU time consumed by the calling thread.
pub fn thread_cpu_time() -> Duration {
    imp::thread_cpu_time()
}

/// Stopwatch over thread CPU time.
pub struct CpuTimer {
    start: Duration,
}

impl Default for CpuTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl CpuTimer {
    pub fn new() -> Self {
        CpuTimer { start: thread_cpu_time() }
    }

    pub fn elapsed(&self) -> Duration {
        thread_cpu_time().saturating_sub(self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_work_accumulates_cpu_time() {
        let t = CpuTimer::new();
        let mut x = 0u64;
        for i in 0..20_000_000u64 {
            x = x.wrapping_add(i).rotate_left(7);
        }
        std::hint::black_box(x);
        assert!(t.elapsed() > Duration::from_millis(1));
    }

    #[test]
    fn sleep_does_not_count() {
        let t = CpuTimer::new();
        std::thread::sleep(Duration::from_millis(50));
        assert!(t.elapsed() < Duration::from_millis(20));
    }
}
