//! Minimal JSON parser (no serde in the vendored dep set).
//!
//! Covers the subset the artifact manifest and config files use: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Strict enough
//! to reject malformed input with a position, small enough to audit.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError(pub usize, pub &'static str);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.0, self.1)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError(p.i, "trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (for config dumps and results files).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError(self.i, "unexpected character"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError(self.i, "expected value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError(self.i, "bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError(start, "bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError(self.i, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(JsonError(self.i, "bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError(self.i, "bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError(self.i, "bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(JsonError(self.i, "bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError(start, "bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(JsonError(self.i, "expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError(self.i, "expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"version": 1, "artifacts": [{"key": "a", "batch": 1024, "adv_temp": null, "ok": true}]}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("key").unwrap().as_str(), Some("a"));
        assert_eq!(arts[0].get("batch").unwrap().as_usize(), Some(1024));
        assert_eq!(arts[0].get("adv_temp"), Some(&Json::Null));
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\"A"));
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"[[1,2],[3,[4]],{"x":[]}]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }

    #[test]
    fn serialize_roundtrip() {
        let s = r#"{"b":[1,2.5,"x"],"a":null}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }
}
