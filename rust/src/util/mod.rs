//! Shared substrate utilities: RNG, alias sampling, timing, fork-join
//! helpers, byte codecs, and ranking helpers.

pub mod alias;
pub mod bytes;
pub mod cputime;
pub mod rng;
pub mod sync;
pub mod threadpool;
pub mod json;
pub mod timer;
pub mod topk;
pub mod ulp;
