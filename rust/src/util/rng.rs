//! Deterministic, fast PRNG used throughout dglke-rs.
//!
//! We implement xoshiro256** (Blackman & Vigna) seeded through SplitMix64.
//! No external `rand` dependency: every sampler, generator and test in the
//! repo draws from this, so runs are reproducible from a single `--seed`.

/// SplitMix64 step — used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. `Clone` is cheap; cloning forks the stream state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for worker `i` (used to give each
    /// trainer/updater thread its own generator).
    pub fn fork(&self, i: u64) -> Rng {
        // Mix the stream index into a fresh SplitMix64 chain so forked
        // streams are decorrelated from each other and from the parent.
        let mut sm = self.s[0] ^ self.s[3] ^ (i.wrapping_mul(0xA076_1D64_78BD_642F));
        Rng::seed_from_u64(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; embedding init is not on the hot path).
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = (self.gen_f64().max(1e-300)) as f64;
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.gen_index(i + 1);
            data.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from `[0, bound)` (bound >= n).
    /// Uses rejection for small n relative to bound, partial shuffle otherwise.
    pub fn sample_distinct(&mut self, bound: usize, n: usize) -> Vec<usize> {
        assert!(n <= bound);
        if n * 8 < bound {
            let mut seen = std::collections::HashSet::with_capacity(n * 2);
            let mut out = Vec::with_capacity(n);
            while out.len() < n {
                let v = self.gen_index(bound);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..bound).collect();
            for i in 0..n {
                let j = i + self.gen_index(bound - i);
                idx.swap(i, j);
            }
            idx.truncate(n);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let base = Rng::seed_from_u64(7);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = r.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn range_roughly_uniform() {
        let mut r = Rng::seed_from_u64(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.gen_index(8)] += 1;
        }
        for &c in &counts {
            let expect = n / 8;
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64);
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..10_000 {
            let v = r.gen_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(13);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.gen_normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn distinct_samples() {
        let mut r = Rng::seed_from_u64(19);
        for (bound, n) in [(1000, 10), (50, 50), (64, 32)] {
            let s = r.sample_distinct(bound, n);
            assert_eq!(s.len(), n);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), n);
            assert!(s.iter().all(|&x| x < bound));
        }
    }
}
