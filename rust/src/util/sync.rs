//! Synchronization abstraction layer for loom-style model checking.
//!
//! Every concurrency-critical module (`store::cache`, `store::racy`,
//! `train::prefetch`, `train::sync`, `kvstore::window`, `kvstore::comm`)
//! imports its primitives from here instead of `std::sync`:
//!
//! * **Normal builds** (`cfg(not(loom))`): pure re-exports of `std::sync`
//!   — zero-cost, type-identical to using std directly.
//! * **Model-checking builds** (`RUSTFLAGS="--cfg loom"`): drop-in
//!   wrapper types that delegate to std but inject deterministic,
//!   seed-varied scheduling perturbation (`yield`/short sleeps) at every
//!   synchronization point, and a [`model`] runner that executes a test
//!   closure under many distinct perturbation seeds.
//!
//! The wrappers are API-compatible with the `loom` crate's model for the
//! subset this repo uses, so when a vendored `loom` becomes available the
//! `cfg(loom)` arm can re-export `loom::sync` instead with no call-site
//! changes. Until then the harness is a *bounded stress exploration*, not
//! an exhaustive interleaving proof: it widens the schedule space far
//! beyond what a bare `cargo test` run explores (every lock acquisition,
//! atomic op, and channel op is a potential preemption point), which is
//! what catches lost-wakeup, lost-write-back, and ordering bugs in
//! practice. The invariants each loom test checks are cataloged in
//! `docs/CONCURRENCY.md`.
//!
//! Tests live in `rust/tests/loom_tests.rs` (gated `#![cfg(loom)]`) and
//! run via `make loom`.

#[cfg(not(loom))]
mod imp {
    pub use std::sync::atomic;
    pub use std::sync::mpsc;
    pub use std::sync::{
        Arc, Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
        RwLockWriteGuard,
    };

    /// Scheduling perturbation point — a no-op outside loom builds.
    #[inline(always)]
    pub fn explore() {}

    /// Run `f` once (the loom build runs it under many schedules).
    pub fn model<F: FnMut()>(mut f: F) {
        f();
    }
}

#[cfg(loom)]
mod imp {
    use std::cell::Cell;
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    use std::sync::atomic::AtomicU64 as StdAtomicU64;
    use std::sync::atomic::Ordering as StdOrdering;
    use std::time::Duration;

    pub use std::sync::{
        Arc, BarrierWaitResult, MutexGuard, RwLockReadGuard, RwLockWriteGuard,
    };

    /// Seed of the current model iteration (0 outside [`model`]).
    static MODEL_SEED: StdAtomicU64 = StdAtomicU64::new(0);

    thread_local! {
        static RNG: Cell<u64> = const { Cell::new(0) };
    }

    /// Scheduling perturbation point: with per-thread seeded xorshift
    /// state, sometimes yield, sometimes briefly sleep, usually proceed.
    /// Called by every wrapper on every synchronization operation.
    pub fn explore() {
        RNG.with(|r| {
            let mut s = r.get();
            if s == 0 {
                // lazily mix the model seed with this thread's identity so
                // sibling threads diverge within one iteration
                let mut h = DefaultHasher::new();
                std::thread::current().id().hash(&mut h);
                s = (MODEL_SEED.load(StdOrdering::Relaxed) ^ h.finish()) | 1;
            }
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            r.set(s);
            match s % 16 {
                0..=4 => std::thread::yield_now(),
                5 => std::thread::sleep(Duration::from_micros(s % 61)),
                _ => {}
            }
        });
    }

    /// Run `f` under many perturbation seeds (default 48; override with
    /// `LOOM_MAX_ITERS`). The analogue of `loom::model`.
    pub fn model<F: FnMut()>(mut f: F) {
        let iters: u64 = std::env::var("LOOM_MAX_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(48);
        for i in 0..iters {
            MODEL_SEED.store(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i + 1), StdOrdering::Relaxed);
            RNG.with(|r| r.set(0)); // reseed the driver thread per iteration
            f();
        }
    }

    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub const fn new(v: T) -> Self {
            Mutex(std::sync::Mutex::new(v))
        }

        pub fn lock(&self) -> std::sync::LockResult<MutexGuard<'_, T>> {
            explore();
            let g = self.0.lock();
            explore();
            g
        }
    }

    pub struct Condvar(std::sync::Condvar);

    impl Condvar {
        pub const fn new() -> Self {
            Condvar(std::sync::Condvar::new())
        }

        pub fn wait<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
        ) -> std::sync::LockResult<MutexGuard<'a, T>> {
            explore();
            self.0.wait(guard)
        }

        pub fn notify_one(&self) {
            explore();
            self.0.notify_one();
        }

        pub fn notify_all(&self) {
            explore();
            self.0.notify_all();
        }
    }

    impl Default for Condvar {
        fn default() -> Self {
            Self::new()
        }
    }

    pub struct RwLock<T>(std::sync::RwLock<T>);

    impl<T> RwLock<T> {
        pub const fn new(v: T) -> Self {
            RwLock(std::sync::RwLock::new(v))
        }

        pub fn read(&self) -> std::sync::LockResult<RwLockReadGuard<'_, T>> {
            explore();
            self.0.read()
        }

        pub fn write(&self) -> std::sync::LockResult<RwLockWriteGuard<'_, T>> {
            explore();
            self.0.write()
        }
    }

    pub struct Barrier(std::sync::Barrier);

    impl Barrier {
        pub fn new(n: usize) -> Self {
            Barrier(std::sync::Barrier::new(n))
        }

        pub fn wait(&self) -> BarrierWaitResult {
            explore();
            let r = self.0.wait();
            explore();
            r
        }
    }

    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        macro_rules! perturbed_atomic {
            ($name:ident, $inner:path, $ty:ty) => {
                #[derive(Default)]
                pub struct $name($inner);

                impl $name {
                    pub const fn new(v: $ty) -> Self {
                        $name(<$inner>::new(v))
                    }

                    pub fn load(&self, o: Ordering) -> $ty {
                        super::explore();
                        self.0.load(o)
                    }

                    pub fn store(&self, v: $ty, o: Ordering) {
                        super::explore();
                        self.0.store(v, o);
                        super::explore();
                    }

                    pub fn fetch_add(&self, v: $ty, o: Ordering) -> $ty {
                        super::explore();
                        let r = self.0.fetch_add(v, o);
                        super::explore();
                        r
                    }

                    pub fn fetch_sub(&self, v: $ty, o: Ordering) -> $ty {
                        super::explore();
                        let r = self.0.fetch_sub(v, o);
                        super::explore();
                        r
                    }
                }
            };
        }

        perturbed_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        perturbed_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

        #[derive(Default)]
        pub struct AtomicBool(std::sync::atomic::AtomicBool);

        impl AtomicBool {
            pub const fn new(v: bool) -> Self {
                AtomicBool(std::sync::atomic::AtomicBool::new(v))
            }

            pub fn load(&self, o: Ordering) -> bool {
                super::explore();
                self.0.load(o)
            }

            pub fn store(&self, v: bool, o: Ordering) {
                super::explore();
                self.0.store(v, o);
                super::explore();
            }
        }
    }

    pub mod mpsc {
        pub use std::sync::mpsc::{RecvError, SendError, TryRecvError, TrySendError};

        pub struct Sender<T>(std::sync::mpsc::Sender<T>);

        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Self {
                Sender(self.0.clone())
            }
        }

        impl<T> Sender<T> {
            pub fn send(&self, v: T) -> Result<(), SendError<T>> {
                super::explore();
                self.0.send(v)
            }
        }

        pub struct SyncSender<T>(std::sync::mpsc::SyncSender<T>);

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> Self {
                SyncSender(self.0.clone())
            }
        }

        impl<T> SyncSender<T> {
            pub fn send(&self, v: T) -> Result<(), SendError<T>> {
                super::explore();
                let r = self.0.send(v);
                super::explore();
                r
            }

            pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
                super::explore();
                self.0.try_send(v)
            }
        }

        pub struct Receiver<T>(std::sync::mpsc::Receiver<T>);

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                super::explore();
                let r = self.0.recv();
                super::explore();
                r
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                super::explore();
                self.0.try_recv()
            }
        }

        pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
            let (tx, rx) = std::sync::mpsc::channel();
            (Sender(tx), Receiver(rx))
        }

        pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
            let (tx, rx) = std::sync::mpsc::sync_channel(bound);
            (SyncSender(tx), Receiver(rx))
        }
    }
}

pub use imp::*;
