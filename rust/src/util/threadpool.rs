//! Minimal scoped fork-join helpers (no rayon in the vendored dep set).
//!
//! The trainers use long-lived dedicated threads (`train/`); this module
//! covers the remaining data-parallel chores: parallel init, parallel eval
//! sharding, and the partitioner's parallel refinement sweeps.

/// Data-parallel thread count for one-shot chores (parallel init, table
/// export): the machine's `available_parallelism`, clamped to `[1, cap]`
/// so small machines aren't oversubscribed and big ones aren't capped at
/// a hard-coded constant.
pub fn default_threads(cap: usize) -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, cap.max(1))
}

/// Run `f(worker_id)` on `n` scoped threads and collect the results in
/// worker order. Panics propagate.
pub fn scoped_map<R: Send>(n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    assert!(n > 0);
    if n == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n).map(|i| s.spawn({ let f = &f; move || f(i) })).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Split `len` items into `n` contiguous ranges (first `len % n` ranges get
/// one extra item). Ranges may be empty when `len < n`.
pub fn split_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    assert!(n > 0);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

/// Parallel for over chunks of a slice: `f(chunk_index, range)`.
pub fn parallel_chunks(len: usize, n: usize, f: impl Fn(usize, std::ops::Range<usize>) + Sync) {
    let ranges = split_ranges(len, n);
    scoped_map(n, |i| f(i, ranges[i].clone()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_collects_in_order() {
        let out = scoped_map(4, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn default_threads_clamps() {
        assert_eq!(default_threads(1), 1);
        let n = default_threads(16);
        assert!((1..=16).contains(&n));
        assert!(default_threads(0) == 1);
    }

    #[test]
    fn ranges_cover_everything() {
        for (len, n) in [(10, 3), (0, 2), (7, 7), (3, 5), (100, 8)] {
            let ranges = split_ranges(len, n);
            assert_eq!(ranges.len(), n);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, len);
            // contiguous & ordered
            let mut pos = 0;
            for r in &ranges {
                assert_eq!(r.start, pos);
                pos = r.end;
            }
        }
    }

    #[test]
    fn parallel_chunks_visits_all() {
        let counter = AtomicUsize::new(0);
        parallel_chunks(1000, 4, |_, r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }
}
