//! Lightweight timing + phase accounting used by the trainers and benches.

use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Accumulates time spent per named phase (sample / gather / compute /
/// update / transfer ...). Cheap enough to keep on the training hot loop.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(&'static str, Duration)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        for e in &mut self.entries {
            if e.0 == phase {
                e.1 += d;
                return;
            }
        }
        self.entries.push((phase, d));
    }

    /// Time a closure, attributing it to `phase`.
    pub fn time<R>(&mut self, phase: &'static str, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.add(phase, t.elapsed());
        r
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.entries
            .iter()
            .find(|e| e.0 == phase)
            .map(|e| e.1)
            .unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|e| e.1).sum()
    }

    /// Merge another PhaseTimes (e.g. from a worker thread) into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for &(p, d) in &other.entries {
            self.add(p, d);
        }
    }

    pub fn entries(&self) -> &[(&'static str, Duration)] {
        &self.entries
    }

    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut s = String::new();
        for &(p, d) in &self.entries {
            let secs = d.as_secs_f64();
            s.push_str(&format!("{p}: {secs:.3}s ({:.1}%)  ", 100.0 * secs / total));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accumulation() {
        let mut pt = PhaseTimes::new();
        pt.add("a", Duration::from_millis(10));
        pt.add("b", Duration::from_millis(5));
        pt.add("a", Duration::from_millis(10));
        assert_eq!(pt.get("a"), Duration::from_millis(20));
        assert_eq!(pt.get("b"), Duration::from_millis(5));
        assert_eq!(pt.total(), Duration::from_millis(25));
    }

    #[test]
    fn merge_workers() {
        let mut a = PhaseTimes::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimes::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(3));
        assert_eq!(a.get("y"), Duration::from_millis(3));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimes::new();
        let v = pt.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(pt.get("work") > Duration::ZERO || pt.get("work") == Duration::ZERO);
    }
}
