//! Rank computation helpers for link-prediction evaluation.

/// Rank of the positive among candidates, 1-based, using *optimistic tie
/// breaking minus half* ("average" protocol): rank = 1 + #{better} +
/// #{ties}/2. This matches common KGE eval implementations and is stable
/// under score ties from saturated models.
pub fn rank_of(positive_score: f32, candidate_scores: &[f32]) -> f64 {
    let mut better = 0usize;
    let mut ties = 0usize;
    for &s in candidate_scores {
        if s > positive_score {
            better += 1;
        } else if s == positive_score {
            ties += 1;
        }
    }
    1.0 + better as f64 + ties as f64 / 2.0
}

/// Indices of the k largest values (descending). O(n log k).
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Entry(f32, usize); // min-heap on score
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal).then(o.1.cmp(&self.1))
        }
    }

    let k = k.min(scores.len());
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(s, i));
        } else if let Some(min) = heap.peek() {
            if s > min.0 {
                heap.pop();
                heap.push(Entry(s, i));
            }
        }
    }
    let mut out: Vec<(f32, usize)> = heap.into_iter().map(|e| (e.0, e.1)).collect();
    out.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    out.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_no_ties() {
        assert_eq!(rank_of(5.0, &[1.0, 9.0, 3.0]), 2.0); // one better
        assert_eq!(rank_of(10.0, &[1.0, 9.0, 3.0]), 1.0);
        assert_eq!(rank_of(0.0, &[1.0, 9.0, 3.0]), 4.0);
    }

    #[test]
    fn rank_ties_average() {
        assert_eq!(rank_of(5.0, &[5.0, 5.0]), 2.0); // 1 + 0 + 1
    }

    #[test]
    fn topk_basic() {
        let s = [0.1, 0.9, 0.5, 0.7];
        assert_eq!(top_k_indices(&s, 2), vec![1, 3]);
        assert_eq!(top_k_indices(&s, 10), vec![1, 3, 2, 0]);
    }

    #[test]
    fn topk_against_sort() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(9);
        let scores: Vec<f32> = (0..500).map(|_| rng.gen_f32()).collect();
        let got = top_k_indices(&scores, 25);
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
        assert_eq!(got, idx[..25].to_vec());
    }
}
