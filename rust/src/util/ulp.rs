//! f32 ULP (units in the last place) distance — the comparator behind the
//! kernel parity goldens (`docs/KERNELS.md`).
//!
//! The fused kernels in `models::kernels` promise bit-exactness against the
//! scalar reference for `Dot`/`SqDiff`/`L1` and a drift bound of at most
//! 2 ULP for `L2`. "ULP distance" here is the number of representable
//! `f32` values strictly between two floats, computed on the monotone
//! integer mapping of IEEE-754 bit patterns (negative floats are mapped
//! below positives, so the distance is well defined across zero).

/// Map an `f32`'s bit pattern onto a monotonically increasing `i64`:
/// ordering the mapped values matches ordering the floats (with
/// `-0.0 == 0.0` one step apart, the standard lexicographic convention).
fn monotone_bits(x: f32) -> i64 {
    let b = x.to_bits() as i64;
    if b < 0x8000_0000 {
        b
    } else {
        // negative floats: flip to descending-magnitude order below zero
        0x8000_0000i64 - b
    }
}

/// ULP distance between two finite `f32`s. `0` means bit-identical (or
/// `+0.0` vs `-0.0` after one step — callers comparing exact bits should
/// use `to_bits` equality). NaNs and infinities never compare close:
/// any non-finite operand yields `i64::MAX` unless both are bit-equal.
pub fn ulp_distance(a: f32, b: f32) -> i64 {
    if a.to_bits() == b.to_bits() {
        return 0;
    }
    if !a.is_finite() || !b.is_finite() {
        return i64::MAX;
    }
    (monotone_bits(a) - monotone_bits(b)).abs()
}

/// `true` when `a` and `b` are within `max_ulp` representable values of
/// each other (see [`ulp_distance`]).
pub fn within_ulp(a: f32, b: f32, max_ulp: i64) -> bool {
    ulp_distance(a, b) <= max_ulp
}

/// Maximum ULP distance across two equal-length slices (panics on length
/// mismatch — a parity harness comparing different shapes is a test bug).
pub fn max_ulp_distance(a: &[f32], b: &[f32]) -> i64 {
    assert_eq!(a.len(), b.len(), "ulp comparison over mismatched lengths");
    a.iter().zip(b).map(|(&x, &y)| ulp_distance(x, y)).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_bits_are_zero_ulp() {
        assert_eq!(ulp_distance(1.5, 1.5), 0);
        assert_eq!(ulp_distance(-0.0, -0.0), 0);
        assert_eq!(ulp_distance(f32::NAN, f32::NAN), 0); // same payload
    }

    #[test]
    fn adjacent_floats_are_one_ulp() {
        let x = 1.0f32;
        let next = f32::from_bits(x.to_bits() + 1);
        assert_eq!(ulp_distance(x, next), 1);
        let y = -2.5f32;
        let next = f32::from_bits(y.to_bits() + 1); // toward -inf in bits
        assert_eq!(ulp_distance(y, next), 1);
    }

    #[test]
    fn distance_crosses_zero() {
        // -0.0 and +0.0 are one step apart; the smallest positive and
        // smallest negative subnormals are two steps apart
        assert_eq!(ulp_distance(0.0, -0.0), 1);
        let tiny_pos = f32::from_bits(1);
        let tiny_neg = f32::from_bits(0x8000_0001);
        assert_eq!(ulp_distance(tiny_pos, tiny_neg), 2);
        assert!(within_ulp(tiny_pos, tiny_neg, 2));
        assert!(!within_ulp(tiny_pos, tiny_neg, 1));
    }

    #[test]
    fn non_finite_never_close() {
        assert_eq!(ulp_distance(f32::INFINITY, f32::MAX), i64::MAX);
        assert_eq!(ulp_distance(f32::NAN, 0.0), i64::MAX);
    }

    #[test]
    fn slice_max_takes_the_worst_pair() {
        let a = [1.0f32, 2.0, 3.0];
        let mut b = a;
        b[1] = f32::from_bits(b[1].to_bits() + 2);
        assert_eq!(max_ulp_distance(&a, &b), 2);
        assert_eq!(max_ulp_distance(&[], &[]), 0);
    }
}
