//! Integration tests for the typed run API (`api::RunSpec` / `Session`):
//! JSON round-trips, builder validation, spec-vs-flags equivalence, and
//! checkpoint export/import.

use dglke::api::{
    EvalProtocolSpec, EvalSpec, ParallelMode, RunSpec, Session, DEFAULT_NATIVE_SHAPE,
};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::store::EmbeddingStore;

/// A small deterministic spec: native backend, 1 worker, sync updates
/// (async updates apply gradients on a second thread, which is
/// deliberately racy — Hogwild).
fn tiny_spec() -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 40,
        lr: 0.25,
        log_every: 10,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn json_round_trip_produces_identical_run() {
    let spec = tiny_spec();
    // serialize → parse → the specs are equal…
    let parsed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(spec, parsed);
    // …and the runs are identical (same seed ⇒ same final loss, same curve)
    let report_a = Session::from_spec(spec).unwrap().train().unwrap();
    let report_b = Session::from_spec(parsed).unwrap().train().unwrap();
    assert_eq!(report_a.final_loss, report_b.final_loss);
    assert_eq!(report_a.loss_curve, report_b.loss_curve);
    assert_eq!(report_a.total_batches, report_b.total_batches);
}

#[test]
fn report_serializes_run_results() {
    let mut spec = tiny_spec();
    spec.eval = Some(EvalSpec {
        protocol: EvalProtocolSpec::Sampled { uniform: 50, degree: 50 },
        max_triplets: 20,
        n_threads: 2,
    });
    let report = Session::from_spec(spec.clone()).unwrap().train().unwrap();
    assert!(report.metrics.is_some(), "spec requested eval");
    let j = dglke::util::json::Json::parse(&report.to_json_string()).unwrap();
    assert_eq!(j.get("mode").unwrap().as_str(), Some("single"));
    assert_eq!(j.get("total_batches").unwrap().as_usize(), Some(40));
    // the producing spec is embedded for provenance and round-trips
    let embedded = RunSpec::from_json(j.get("spec").unwrap()).unwrap();
    assert_eq!(embedded, spec);
}

#[test]
fn builder_equals_config_file() {
    // the committed quickstart spec and the equivalent builder calls (the
    // flag-based CLI path goes through the same builder fields)
    let text = std::fs::read_to_string("examples/specs/quickstart.json").unwrap();
    let from_file = RunSpec::from_json_str(&text).unwrap();
    let from_builder = Session::builder()
        .dataset("fb15k-syn")
        .model(ModelKind::TransEL2)
        .backend(BackendKind::Native)
        .workers(2)
        .batches(250)
        .lr(0.3)
        .sync_interval(100)
        .log_every(25)
        .eval(EvalSpec {
            protocol: EvalProtocolSpec::FullFiltered,
            max_triplets: 500,
            n_threads: 4,
        })
        .seed(42)
        .into_spec();
    assert_eq!(from_file, from_builder);
}

#[test]
fn builder_validation_errors() {
    // unknown dataset (neither preset nor directory)
    let err = Session::builder().dataset("no-such-dataset").build().unwrap_err();
    assert!(err.to_string().contains("no-such-dataset"), "{err}");

    // zero workers
    let mut spec = tiny_spec();
    spec.mode = ParallelMode::Single { workers: 0, gpu: false };
    assert!(Session::from_spec(spec).is_err());

    // zero machines
    let mut spec = tiny_spec();
    spec.mode = ParallelMode::Distributed {
        machines: 0,
        trainers: 1,
        servers: 1,
        partition: dglke::dist::PartitionStrategy::Metis,
        local_negatives: true,
    };
    assert!(Session::from_spec(spec).is_err());

    // missing artifacts for the XLA backend
    if !dglke::runtime::artifacts::available() {
        let mut spec = tiny_spec();
        spec.backend = BackendKind::Xla;
        spec.shape = None;
        let err = Session::from_spec(spec).unwrap_err();
        assert!(err.to_string().contains("artifacts"), "{err}");
    }
}

#[test]
fn native_default_shape_is_explicit() {
    // without artifacts or an explicit shape, the native backend falls
    // back to the documented default — not a buried literal
    if dglke::runtime::artifacts::available() {
        return; // resolution would use the real artifacts
    }
    let mut spec = tiny_spec();
    spec.shape = None;
    let session = Session::from_spec(spec).unwrap();
    assert_eq!(session.step_shape(), DEFAULT_NATIVE_SHAPE);
    assert_eq!(session.dim(), DEFAULT_NATIVE_SHAPE.dim);
}

#[test]
fn export_and_load_checkpoint_round_trip() {
    let dir = std::env::temp_dir().join(format!("dglke_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut session = Session::from_spec(tiny_spec()).unwrap();
    session.train().unwrap();
    let trained_ents = session.state().entities.snapshot();
    let trained_rels = session.state().relations.snapshot();
    session.export_embeddings(&dir).unwrap();

    // a fresh session has different (random-init) embeddings…
    let mut fresh = Session::from_spec(RunSpec { seed: 999, ..tiny_spec() }).unwrap();
    assert_ne!(fresh.state().entities.snapshot(), trained_ents);
    // …until the checkpoint is loaded
    fresh.load_checkpoint(&dir).unwrap();
    assert_eq!(fresh.state().entities.snapshot(), trained_ents);
    assert_eq!(fresh.state().relations.snapshot(), trained_rels);

    // and the restored embeddings evaluate identically (same eval seed)
    let m_trained = session.evaluate().unwrap();
    let mut same_seed = Session::from_spec(tiny_spec()).unwrap();
    same_seed.load_checkpoint(&dir).unwrap();
    let m_same = same_seed.evaluate().unwrap();
    assert_eq!(m_trained, m_same);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_mismatch_rejected() {
    let dir = std::env::temp_dir().join(format!("dglke_ckpt_mismatch_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let session = Session::from_spec(tiny_spec()).unwrap();
    session.export_embeddings(&dir).unwrap();

    // different model → rejected
    let mut other = Session::from_spec(RunSpec {
        model: ModelKind::DistMult,
        ..tiny_spec()
    })
    .unwrap();
    assert!(other.load_checkpoint(&dir).is_err());

    // different dim → rejected
    let mut other = Session::from_spec(RunSpec {
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 32 }),
        ..tiny_spec()
    })
    .unwrap();
    assert!(other.load_checkpoint(&dir).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn distributed_session_trains_and_evaluates() {
    let spec = RunSpec {
        dataset: "tiny".into(),
        backend: BackendKind::Native,
        mode: ParallelMode::Distributed {
            machines: 2,
            trainers: 1,
            servers: 1,
            partition: dglke::dist::PartitionStrategy::Metis,
            local_negatives: true,
        },
        batches: 20,
        lr: 0.25,
        log_every: 5,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        eval: Some(EvalSpec {
            protocol: EvalProtocolSpec::Sampled { uniform: 50, degree: 50 },
            max_triplets: 20,
            n_threads: 2,
        }),
        seed: 3,
        ..Default::default()
    };
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    assert_eq!(report.mode, "distributed");
    assert_eq!(report.total_batches, 2 * 20);
    assert!(report.locality > 0.0);
    assert!(report.metrics.is_some());
    // the cluster dump landed in the session state: embeddings are usable
    assert_eq!(session.state().entities.rows(), session.dataset().n_entities());
}
