//! Remote-TCP-path equivalence and barrier tests for the async KVStore
//! comms (`kvstore::comm`).
//!
//! The core claim: with a *single* trainer and synchronous (drained)
//! updates, the async/pipelined client — and the distributed prefetch
//! pipeline on top of it — is byte-identical to the sequential
//! round-trip client, on both partition strategies. A single trainer
//! against a multi-machine cluster cannot be expressed through
//! `DistConfig` (trainers are per machine), so these tests drive
//! `dist::run_trainer` directly over a 2-machine cluster: machine 1's
//! shard is remote from the trainer on machine 0, so every run exercises
//! the real TCP path.

use dglke::dist::{run_trainer, DistConfig, PartitionStrategy};
use dglke::kg::Dataset;
use dglke::kvstore::{CommHandle, KvCluster, TableId};
use dglke::models::step::StepShape;
use dglke::partition::{GraphPartition, MetisConfig};
use dglke::runtime::BackendKind;
use dglke::store::EmbeddingStore;

const SHAPE: StepShape = StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 };
const MACHINES: usize = 2;

struct RunOut {
    ents: Vec<f32>,
    rels: Vec<f32>,
    losses: Vec<(u64, f32)>,
    remote_bytes: u64,
    overlapped_bytes: u64,
}

/// One trainer (on machine 0) over a 2-machine cluster, under the given
/// comm mode. Everything except the comm path is held fixed, so outputs
/// are comparable bit for bit.
fn run_single_trainer(
    dataset: &Dataset,
    partition: PartitionStrategy,
    pipelined: bool,
    prefetch: bool,
    seed: u64,
) -> RunOut {
    let part = match partition {
        PartitionStrategy::Metis => {
            GraphPartition::metis(&dataset.train, MACHINES, &MetisConfig::default())
        }
        PartitionStrategy::Random => GraphPartition::random(&dataset.train, MACHINES, seed),
    };
    let cfg = DistConfig {
        backend: BackendKind::Native,
        shape: Some(SHAPE),
        machines: MACHINES,
        trainers_per_machine: 1,
        servers_per_machine: 1,
        partition,
        batches_per_trainer: 25,
        lr: 0.25,
        log_every: 5,
        pipelined,
        inflight: 3,
        prefetch,
        prefetch_depth: 2,
        seed,
        ..Default::default()
    };
    let rel_dim = cfg.model.rel_dim(SHAPE.dim);
    let cluster = KvCluster::start(
        &part.entity_part,
        dataset.n_relations(),
        MACHINES,
        1,
        SHAPE.dim,
        rel_dim,
        cfg.lr,
        cfg.init_scale,
        seed,
    )
    .unwrap();
    let idx: Vec<usize> = (0..dataset.train.len()).collect();
    let out = run_trainer(dataset, None, &cfg, &cluster, 0, 0, &idx, None, 0).unwrap();
    assert_eq!(out.batches, cfg.batches_per_trainer as u64);
    RunOut {
        ents: cluster.dump_entities(dataset.n_entities(), SHAPE.dim).snapshot(),
        rels: cluster.dump_relations(dataset.n_relations(), rel_dim).snapshot(),
        losses: out.losses,
        remote_bytes: cluster.ledger.remote(),
        overlapped_bytes: cluster.ledger.overlapped(),
    }
}

/// The acceptance matrix: async/pipelined comms — with and without the
/// distributed prefetch pipeline — must be byte-identical to the
/// sequential client for 1 trainer under sync (drained) updates, across
/// both partition strategies.
#[test]
fn async_sync_equivalence_matrix() {
    let dataset = Dataset::load("tiny", 21).unwrap();
    for partition in [PartitionStrategy::Random, PartitionStrategy::Metis] {
        let base = run_single_trainer(&dataset, partition, false, false, 33);
        assert!(base.remote_bytes > 0, "2-machine run must cross TCP");
        assert_eq!(base.overlapped_bytes, 0, "sync client is all critical path");
        for (pipelined, prefetch) in [(true, false), (false, true), (true, true)] {
            let got = run_single_trainer(&dataset, partition, pipelined, prefetch, 33);
            let tag = format!(
                "partition {:?} pipelined {pipelined} prefetch {prefetch}",
                partition
            );
            assert_eq!(got.losses, base.losses, "loss trajectory changed: {tag}");
            assert_eq!(got.ents, base.ents, "entity table changed: {tag}");
            assert_eq!(got.rels, base.rels, "relation table changed: {tag}");
            if prefetch {
                // helper pulls are off the critical path; patch re-pulls
                // add remote traffic on top of the base
                assert!(got.overlapped_bytes > 0, "{tag}");
                assert!(got.remote_bytes >= base.remote_bytes, "{tag}");
            } else {
                // identical requests, identical byte accounting; the async
                // client's pushes are billed overlapped
                assert_eq!(got.remote_bytes, base.remote_bytes, "{tag}");
                assert!(got.overlapped_bytes > 0, "{tag}");
            }
            assert!(got.overlapped_bytes <= got.remote_bytes, "{tag}");
        }
    }
}

fn striped_cluster(seed: u64) -> KvCluster {
    let entity_machine: Vec<u32> = (0..40).map(|i| (i % 2) as u32).collect();
    KvCluster::start(&entity_machine, 6, 2, 1, 8, 8, 0.3, 0.2, seed).unwrap()
}

/// The drain barrier: a fire-and-forget push stream, once drained, has
/// applied every gradient exactly once — byte-identical to the same
/// stream pushed synchronously into an identically seeded cluster.
#[test]
fn drain_barrier_loses_no_gradient() {
    let a = striped_cluster(5);
    let b = striped_cluster(5);
    let mut sync_c = a.client(0).unwrap();
    let mut async_c = b.async_client(0, 2, false).unwrap();
    for round in 0..60u64 {
        // 8 distinct ids per round, mixing local and remote rows
        let ids: Vec<u64> = (0..8u64).map(|k| (round * 3 + k * 5) % 40).collect();
        let rows: Vec<f32> =
            (0..ids.len() * 8).map(|v| (v as f32 + round as f32) * 0.01).collect();
        sync_c.push(TableId::Entities, &ids, 8, &rows).unwrap();
        async_c.push(TableId::Entities, &ids, 8, &rows).unwrap();
    }
    async_c.drain().unwrap();
    let (submitted, completed) = async_c.push_marks();
    assert_eq!(submitted, completed, "drain must wait for every ack");
    assert!(submitted > 0);
    let ents_sync = a.dump_entities(40, 8).snapshot();
    let ents_async = b.dump_entities(40, 8).snapshot();
    assert_eq!(ents_sync, ents_async, "a drained push stream must equal the synchronous one");
}

/// Dropping the async client without an explicit drain still flushes the
/// queued pushes (the writer finishes its queue before hanging up) — the
/// barrier is about *when* completion is guaranteed, not *whether*.
#[test]
fn dropping_async_client_flushes_queued_pushes() {
    let a = striped_cluster(9);
    let b = striped_cluster(9);
    let mut sync_c = a.client(0).unwrap();
    {
        let mut async_c = b.async_client(0, 4, false).unwrap();
        for round in 0..10u64 {
            let ids: Vec<u64> = (0..4u64).map(|k| (round + k * 7) % 40).collect();
            let rows: Vec<f32> = (0..ids.len() * 8).map(|v| v as f32 * 0.02).collect();
            sync_c.push(TableId::Entities, &ids, 8, &rows).unwrap();
            async_c.push(TableId::Entities, &ids, 8, &rows).unwrap();
        }
        // no drain: Drop joins the I/O threads after the queue empties
    }
    assert_eq!(a.dump_entities(40, 8).snapshot(), b.dump_entities(40, 8).snapshot());
}

/// `pull` waves through the async client return exactly what the sync
/// client sees, relations included, while a push stream is in flight on
/// the same handle (per-connection ordering).
#[test]
fn interleaved_push_pull_stays_ordered() {
    let cluster = striped_cluster(11);
    let mut c = cluster.async_client(0, 3, false).unwrap();
    let ids: Vec<u64> = (0..40).collect();
    let mut out_before = vec![0f32; 40 * 8];
    c.pull(TableId::Entities, &ids, 8, &mut out_before).unwrap();
    for round in 0..12u64 {
        let push_ids: Vec<u64> = vec![round % 40, (round + 20) % 40];
        let rows = vec![0.5f32; 2 * 8];
        c.push(TableId::Entities, &push_ids, 8, &rows).unwrap();
        // a pull right behind the push must observe it
        let mut got = vec![0f32; 8];
        c.pull(TableId::Entities, &push_ids[..1], 8, &mut got).unwrap();
        let expect = cluster.dump_entities(40, 8);
        // dump reads server state directly; the pull must match it for
        // this row (the push was applied before the pull was answered)
        assert_eq!(got, expect.row_vec((round % 40) as usize), "round {round}");
    }
    c.drain().unwrap();
}
