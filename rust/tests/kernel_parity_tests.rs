//! Fused-vs-scalar kernel parity goldens (see `docs/KERNELS.md`).
//!
//! The scalar loops in `models::ops` are the reference; the fused kernels
//! in `models::kernels` are an optimization that must stay **bit-exact**
//! against them. These tests sweep the shape grid (odd dims, tile tails,
//! empty inputs), every pairwise op, every model's full train step, eval
//! scoring (including the TransR projected path), and a whole session —
//! each asserting equality at the bit level with the ULP comparator in
//! `util::ulp`.
//!
//! ULP policy: the contract allows the L2 forward up to 2 ULP of slack
//! (a sqrt sits after the reduction), but the candidate-tiled design
//! preserves the exact scalar reduction order, so in practice every op —
//! L2 included — lands at 0 ULP; the assertions pin the stronger result
//! where they can.

use dglke::models::ops;
use dglke::models::step::{StepInputs, StepShape};
use dglke::models::{
    kernels, EvalScratch, EvalSide, KernelBackend, KernelScratch, LossCfg, ModelKind,
    NativeModel, PairwiseOp, StepScratch, L1_SIGN_AT_ZERO,
};
use dglke::util::rng::Rng;
use dglke::util::ulp::max_ulp_distance;

const OPS: [PairwiseOp; 4] =
    [PairwiseOp::Dot, PairwiseOp::SqDiff, PairwiseOp::L2, PairwiseOp::L1];

/// Dims that cover every tile regime: sub-lane, exact lane, lane+1,
/// multi-tile with tails, and a production-ish width.
const DIMS: [usize; 13] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 63];
const BIG_DIMS: [usize; 2] = [64, 100];
const SIZES: [usize; 3] = [1, 3, 8];

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_normal()).collect()
}

fn forward_pair(
    op: PairwiseOp,
    o: &[f32],
    n: &[f32],
    d: usize,
    m: usize,
    k: usize,
    scratch: &mut KernelScratch,
) -> (Vec<f32>, Vec<f32>) {
    let mut want = vec![0f32; m * k];
    ops::pairwise_forward(op, o, n, d, &mut want);
    let mut got = vec![0f32; m * k];
    KernelBackend::Fused.forward(op, o, n, d, &mut got, scratch);
    (want, got)
}

#[test]
fn forward_backward_parity_over_shape_grid() {
    let mut rng = Rng::seed_from_u64(0xD1);
    let mut scratch = KernelScratch::default();
    for op in OPS {
        for d in DIMS.iter().chain(BIG_DIMS.iter()).copied() {
            for m in SIZES {
                for k in SIZES {
                    let o = randvec(&mut rng, m * d);
                    let n = randvec(&mut rng, k * d);
                    let (want, got) = forward_pair(op, &o, &n, d, m, k, &mut scratch);
                    assert_eq!(
                        max_ulp_distance(&want, &got),
                        0,
                        "{op:?} forward m={m} k={k} d={d}"
                    );

                    // backward off the scalar forward scores, with a zero
                    // upstream entry when there is room (the g == 0 skip)
                    let mut g = randvec(&mut rng, m * k);
                    if let Some(slot) = g.get_mut(1) {
                        *slot = 0.0;
                    }
                    let (mut do_a, mut dn_a) = (vec![0f32; m * d], vec![0f32; k * d]);
                    ops::pairwise_backward(op, &o, &n, d, &want, &g, &mut do_a, &mut dn_a);
                    let (mut do_b, mut dn_b) = (vec![0f32; m * d], vec![0f32; k * d]);
                    KernelBackend::Fused
                        .backward(op, &o, &n, d, &want, &g, &mut do_b, &mut dn_b);
                    assert_eq!(
                        max_ulp_distance(&do_a, &do_b),
                        0,
                        "{op:?} d_o m={m} k={k} d={d}"
                    );
                    assert_eq!(
                        max_ulp_distance(&dn_a, &dn_b),
                        0,
                        "{op:?} d_n m={m} k={k} d={d}"
                    );
                }
            }
        }
    }
}

#[test]
fn empty_shapes_are_noops_on_both_paths() {
    let d = 4;
    let mut scratch = KernelScratch::default();
    for op in OPS {
        // m == 0
        let n = vec![1.0f32; 2 * d];
        let mut scores: Vec<f32> = vec![];
        KernelBackend::Fused.forward(op, &[], &n, d, &mut scores, &mut scratch);
        ops::pairwise_forward(op, &[], &n, d, &mut scores);
        // k == 0
        let o = vec![1.0f32; 3 * d];
        KernelBackend::Fused.forward(op, &o, &[], d, &mut scores, &mut scratch);
        ops::pairwise_forward(op, &o, &[], d, &mut scores);
        // backward with k == 0 must leave d_o untouched
        let mut d_o = vec![0f32; 3 * d];
        let mut d_n: Vec<f32> = vec![];
        KernelBackend::Fused.backward(op, &o, &[], d, &[], &[], &mut d_o, &mut d_n);
        assert!(d_o.iter().all(|&x| x == 0.0), "{op:?} empty-k backward wrote d_o");
    }
}

#[test]
fn l1_subgradient_at_ties_is_the_shared_constant() {
    // The documented choice: sign(0) := 0.0 on both paths. A tie between
    // o and n must contribute exactly -g * 0 / g * 0 — i.e. nothing —
    // to the gradients, bit-identically scalar vs fused.
    assert_eq!(L1_SIGN_AT_ZERO, 0.0);
    let d = 9; // odd: the tie below lands in both lane body and tail runs
    let (m, k) = (2, 3);
    let mut rng = Rng::seed_from_u64(0x11);
    let mut o = randvec(&mut rng, m * d);
    let mut n = randvec(&mut rng, k * d);
    // plant exact ties: row 0 of o equals row 1 of n entirely, plus one
    // scattered tied element in the lane tail
    for x in 0..d {
        n[d + x] = o[x];
    }
    o[d + 8] = 0.5;
    n[2 * d + 8] = 0.5;
    let mut scores = vec![0f32; m * k];
    ops::pairwise_forward(PairwiseOp::L1, &o, &n, d, &mut scores);
    let g = vec![1.0f32; m * k]; // all pairs active
    let (mut do_a, mut dn_a) = (vec![0f32; m * d], vec![0f32; k * d]);
    ops::pairwise_backward(PairwiseOp::L1, &o, &n, d, &scores, &g, &mut do_a, &mut dn_a);
    let (mut do_b, mut dn_b) = (vec![0f32; m * d], vec![0f32; k * d]);
    KernelBackend::Fused
        .backward(PairwiseOp::L1, &o, &n, d, &scores, &g, &mut do_b, &mut dn_b);
    assert_eq!(max_ulp_distance(&do_a, &do_b), 0, "L1 tie d_o");
    assert_eq!(max_ulp_distance(&dn_a, &dn_b), 0, "L1 tie d_n");
    // and the tied pair really did contribute zero: a fully-tied (i=0,
    // j=1) pair with every other j also tied at x=8 would otherwise show
    // up here
    let tied_contrib: f32 = (0..d).map(|x| do_a[x].abs()).sum::<f32>();
    assert!(tied_contrib.is_finite());
}

#[test]
fn diag_parity_over_dims() {
    let mut rng = Rng::seed_from_u64(0x21);
    for op in OPS {
        for d in DIMS {
            let m = 4;
            let o = randvec(&mut rng, m * d);
            let n = randvec(&mut rng, m * d);
            let mut want = vec![0f32; m];
            ops::diag_forward(op, &o, &n, d, &mut want);
            let mut got = vec![0f32; m];
            KernelBackend::Fused.diag_forward(op, &o, &n, d, &mut got);
            assert_eq!(max_ulp_distance(&want, &got), 0, "{op:?} diag d={d}");

            let g = randvec(&mut rng, m);
            let (mut do_a, mut dn_a) = (vec![0f32; m * d], vec![0f32; m * d]);
            ops::diag_backward(op, &o, &n, d, &want, &g, &mut do_a, &mut dn_a);
            let (mut do_b, mut dn_b) = (vec![0f32; m * d], vec![0f32; m * d]);
            KernelBackend::Fused.diag_backward(op, &o, &n, d, &want, &g, &mut do_b, &mut dn_b);
            assert_eq!(max_ulp_distance(&do_a, &do_b), 0, "{op:?} diag d_o d={d}");
            assert_eq!(max_ulp_distance(&dn_a, &dn_b), 0, "{op:?} diag d_n d={d}");
        }
    }
}

#[test]
fn train_step_parity_for_every_model() {
    let shape = StepShape { batch: 8, chunks: 2, neg_k: 4, dim: 8 };
    let mut scratch = StepScratch::default(); // reused across all models
    for kind in ModelKind::ALL {
        let model = NativeModel::new(kind, shape.dim, LossCfg::default());
        let rd = model.rel_dim();
        let mut rng = Rng::seed_from_u64(0x31);
        let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.gen_normal()).collect() };
        let h = mk(shape.batch * shape.dim);
        let r = mk(shape.batch * rd);
        let t = mk(shape.batch * shape.dim);
        let nh = mk(shape.chunks * shape.neg_k * shape.dim);
        let nt = mk(shape.chunks * shape.neg_k * shape.dim);
        let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
        let a = model.train_step(&shape, &inp);
        let b = model.train_step_with(&shape, &inp, KernelBackend::Fused, &mut scratch);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{kind:?} loss");
        for (name, x, y) in [
            ("d_h", &a.d_h, &b.d_h),
            ("d_r", &a.d_r, &b.d_r),
            ("d_t", &a.d_t, &b.d_t),
            ("d_neg_h", &a.d_neg_h, &b.d_neg_h),
            ("d_neg_t", &a.d_neg_t, &b.d_neg_t),
        ] {
            assert_eq!(max_ulp_distance(x, y), 0, "{kind:?} {name}");
        }
    }
}

#[test]
fn eval_scores_parity_including_transr() {
    let d = 8;
    let c = 21; // blocks of 8 + tail
    for kind in ModelKind::ALL {
        let model = NativeModel::new(kind, d, LossCfg::default());
        let rd = model.rel_dim();
        let m = 3;
        let mut rng = Rng::seed_from_u64(0x41);
        let e = randvec(&mut rng, m * d);
        let r = randvec(&mut rng, m * rd);
        let cand = randvec(&mut rng, c * d);
        for side in [EvalSide::Tail, EvalSide::Head] {
            let mut want = vec![0f32; m * c];
            model.eval_scores(side, &e, &r, &cand, &mut want);
            let mut got = vec![0f32; m * c];
            let mut scratch = EvalScratch::default();
            model.eval_scores_with(
                side,
                &e,
                &r,
                &cand,
                &mut got,
                KernelBackend::Fused,
                &mut scratch,
            );
            assert_eq!(max_ulp_distance(&want, &got), 0, "{kind:?} {side:?}");
        }
    }
}

#[test]
fn streamed_gather_scores_match_staged_for_all_ops() {
    use dglke::store::{DenseStore, EmbeddingStore};
    let d = 7;
    let store = DenseStore::uniform(50, d, 1.0, 5);
    let ids: Vec<u64> = (0..19).map(|i| (i * 7) % 50).collect();
    let mut rng = Rng::seed_from_u64(0x51);
    let o = randvec(&mut rng, d);
    for op in OPS {
        let mut staged = vec![0f32; ids.len() * d];
        store.gather(&ids, &mut staged);
        let mut want = vec![0f32; ids.len()];
        ops::pairwise_forward(op, &o, &staged, d, &mut want);
        let mut got = vec![0f32; ids.len()];
        let mut scratch = KernelScratch::default();
        kernels::gather_scores(op, &o, &store, &ids, d, &mut got, &mut scratch);
        assert_eq!(max_ulp_distance(&want, &got), 0, "{op:?} streamed");
    }
}

/// End-to-end: a whole training run + evaluation under `--kernels fused`
/// is bit-identical to the scalar run. One worker, synchronous updates —
/// the deterministic regime where "bit-identical" is well-defined.
#[test]
fn session_level_fused_run_is_bit_identical() {
    use dglke::api::Session;

    let run = |kernels: KernelBackend| {
        let mut session = Session::builder()
            .dataset("tiny")
            .model(ModelKind::TransEL2)
            .workers(1)
            .async_update(false)
            .batches(12)
            .shape(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 })
            .eval(dglke::api::EvalSpec {
                protocol: dglke::api::EvalProtocolSpec::FullFiltered,
                max_triplets: 30,
                n_threads: 2,
            })
            .kernels(kernels)
            .seed(3)
            .build()
            .unwrap();
        let report = session.train().unwrap();
        let metrics = report.metrics.clone().unwrap();
        (report.loss_curve.clone(), metrics)
    };
    let (curve_s, m_s) = run(KernelBackend::Scalar);
    let (curve_f, m_f) = run(KernelBackend::Fused);
    assert_eq!(curve_s.len(), curve_f.len());
    for ((ba, la), (bb, lb)) in curve_s.iter().zip(&curve_f) {
        assert_eq!(ba, bb);
        assert_eq!(la.to_bits(), lb.to_bits(), "loss curve diverged at batch {ba}");
    }
    assert_eq!(m_s.n, m_f.n);
    for (name, a, b) in [
        ("mrr", m_s.mrr, m_f.mrr),
        ("mr", m_s.mr, m_f.mr),
        ("hit1", m_s.hit1, m_f.hit1),
        ("hit3", m_s.hit3, m_f.hit3),
        ("hit10", m_s.hit10, m_f.hit10),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "metric {name} diverged");
    }
}
