//! Concurrency model tests over `util::sync` (PR 6).
//!
//! Every test body runs under [`model`], which executes it once on the
//! plain std primitives under normal `cargo test`, and many times under
//! seeded schedule perturbation when the tree is built with
//! `RUSTFLAGS="--cfg loom"` (`make loom`). The perturbed world injects
//! yields/sleeps at every lock acquisition, atomic access, and channel
//! op, exploring interleavings a single run would never hit; the shim is
//! API-compatible with the real `loom` crate so these tests can move to
//! exhaustive exploration unchanged once that dependency is available.
//!
//! Each test encodes one contract from docs/CONCURRENCY.md:
//!
//! 1. cache: no write-back is lost when eviction races `flush()`
//! 2. cache: row updates are exact across eviction/refill cycles
//! 3. prefetch: the applied-push stamp (Release) publishes the pushes it
//!    counts to an Acquire reader — a patch never trusts a pre-stamp row
//! 4. kvstore window: a drain barrier observes every prior push
//! 5. kvstore window: a full in-flight window cannot deadlock
//! 6. kvstore window: link failure neither loses nor duplicates entries
//! 7. trainer barrier: exactly one leader per crossing
//! 8. kvstore acks: per-link marks (Release/Acquire) publish server
//!    effects — completion of a mark proves the pushes it counts applied
//! 9. serve swap: readers see old or new snapshot in full, never a torn
//!    mix — a publish replaces the whole `Arc` or nothing
//! 10. serve swap: the wait-free epoch probe never overtakes the
//!     contents — a probe followed by a load sees contents >= the probe
//! 11. obs trace: a concurrent span-buffer drain reads a fully-written
//!     prefix (never a torn record) and loses nothing once the writer
//!     has quiesced

use dglke::kvstore::{InflightWindow, PopOutcome};
use dglke::obs::trace::SpanBuf;
use dglke::serve::Swap;
use dglke::store::{CachedStore, DenseStore, EmbeddingStore};
use dglke::train::sync::SyncState;
use dglke::util::sync::atomic::{AtomicU64, Ordering};
use dglke::util::sync::{explore, model, Arc};

/// 1. The write-back cache races a writer (forcing evictions, each
/// writing back its dirty victim) against repeated `flush()` calls. No
/// interleaving may lose a dirty row: after the dust settles, the
/// *backing* store holds every written value.
#[test]
fn cache_concurrent_evict_flush_loses_no_writeback() {
    model(|| {
        // 48 rows through a 5-row, single-stripe cache: ~43 evictions
        let cache = CachedStore::with_capacity_rows(Box::new(DenseStore::zeros(48, 2)), 5);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..48 {
                    cache.set_row(i, &[i as f32, -(i as f32)]);
                }
            });
            s.spawn(|| {
                for _ in 0..16 {
                    explore();
                    cache.flush().expect("dense-backed flush cannot fail");
                }
            });
        });
        cache.flush().expect("dense-backed flush cannot fail");
        for i in 0..48 {
            assert_eq!(
                cache.inner().row_vec(i),
                vec![i as f32, -(i as f32)],
                "row {i}: write-back lost under concurrent evict+flush"
            );
        }
    });
}

/// 2. Two threads increment every row through a capacity-starved cache,
/// so increments land on cached rows, evicted-then-refilled rows, and
/// rows mid-write-back. The stripe lock makes each read-modify-write
/// atomic: the final count is exact, never lost or doubled.
#[test]
fn cache_concurrent_updates_are_exact() {
    model(|| {
        let cache = CachedStore::with_capacity_rows(Box::new(DenseStore::zeros(16, 1)), 3);
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    for _ in 0..8 {
                        for i in 0..16 {
                            cache.update_row(i, &mut |row| row[0] += 1.0);
                        }
                    }
                });
            }
        });
        for i in 0..16 {
            assert_eq!(cache.row_vec(i), vec![16.0], "row {i}: lost or doubled update");
        }
    });
}

/// 3. The prefetch-stamp protocol (train::prefetch, kvstore's
/// DistPrefetcher, dist::advance_applied): the trainer applies a step's
/// pushes, then advances `applied` with Release; the helper stamps each
/// pull with an Acquire load. A stamp of S must prove the effects of all
/// steps < S are visible — that is exactly what lets the trainer re-pull
/// only rows pushed at or after the stamp (a "pre-stamp" row is
/// guaranteed fresh and is never patched).
#[test]
fn applied_stamp_release_acquire_publishes_pushes() {
    model(|| {
        let applied = Arc::new(AtomicU64::new(0));
        let pushes_applied = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            let stamp = applied.clone();
            let srv = pushes_applied.clone();
            s.spawn(move || {
                for step in 1..=64u64 {
                    srv.fetch_add(1, Ordering::Relaxed); // step's push applies
                    stamp.store(step, Ordering::Release); // then the stamp advances
                }
            });
            for _ in 0..64 {
                explore();
                let stamp = applied.load(Ordering::Acquire);
                let seen = pushes_applied.load(Ordering::Relaxed);
                assert!(
                    seen >= stamp,
                    "stamp {stamp} observed but only {seen} pushes visible: \
                     a patch would trust a stale pre-stamp row"
                );
            }
        });
    });
}

enum Entry {
    Push(u64),
    /// barrier carrying the push count it must observe
    Drain(u64),
}

/// 4. The drain barrier: an entry enqueued after N pushes pops only
/// after all N — `drain()`'s ack therefore proves every prior push was
/// answered. This is the FIFO half of the CommHandle::drain contract.
#[test]
fn window_drain_observes_every_prior_push() {
    model(|| {
        let win = Arc::new(InflightWindow::new(4));
        std::thread::scope(|s| {
            let w = win.clone();
            s.spawn(move || {
                let mut sent = 0u64;
                for _ in 0..6 {
                    for _ in 0..5 {
                        sent += 1;
                        assert!(w.enqueue(Entry::Push(sent)).is_ok());
                    }
                    assert!(w.enqueue(Entry::Drain(sent)).is_ok());
                }
                w.close();
            });
            let mut acked = 0u64;
            loop {
                match win.pop() {
                    PopOutcome::Entry(Entry::Push(n)) => {
                        assert_eq!(n, acked + 1, "push popped out of order");
                        acked = n;
                    }
                    PopOutcome::Entry(Entry::Drain(expect)) => {
                        assert_eq!(acked, expect, "drain popped before a prior push");
                    }
                    PopOutcome::Closed => break,
                    PopOutcome::Failed => panic!("window failed"),
                }
            }
            assert_eq!(acked, 30, "pushes lost");
        });
    });
}

/// 5. A window far smaller than the traffic it carries: the producer
/// blocks on `space`, the consumer on `nonempty`, and every schedule
/// must still move all 64 entries through in order — no lost-wakeup
/// deadlock at the full-window boundary.
#[test]
fn full_inflight_window_never_deadlocks() {
    model(|| {
        let win = Arc::new(InflightWindow::new(2));
        std::thread::scope(|s| {
            let w = win.clone();
            s.spawn(move || {
                for i in 0..64u64 {
                    assert!(w.enqueue(i).is_ok());
                }
                w.close();
            });
            let mut expect = 0u64;
            loop {
                match win.pop() {
                    PopOutcome::Entry(v) => {
                        assert_eq!(v, expect);
                        expect += 1;
                    }
                    PopOutcome::Closed => break,
                    PopOutcome::Failed => panic!("window failed"),
                }
            }
            assert_eq!(expect, 64);
        });
    });
}

/// 6. Link failure: whatever the interleaving, every successfully
/// enqueued entry is accounted for exactly once — popped by the reader
/// or drained by `fail()` for failure delivery — and the blocked/next
/// producer gets its entry back. Nothing is lost, nothing delivered
/// twice, nothing blocks forever.
#[test]
fn window_failure_neither_loses_nor_duplicates_entries() {
    model(|| {
        let win = Arc::new(InflightWindow::new(2));
        let mut popped = Vec::new();
        let (enqueued, rejected, drained) = std::thread::scope(|s| {
            let w = win.clone();
            let producer = s.spawn(move || {
                for i in 0..1000u64 {
                    explore();
                    if let Err(v) = w.enqueue(i) {
                        return (i, Some(v));
                    }
                }
                (1000, None)
            });
            for _ in 0..5 {
                match win.pop() {
                    PopOutcome::Entry(v) => popped.push(v),
                    _ => panic!("window closed/failed before the reader was done"),
                }
            }
            let drained = win.fail();
            let (enqueued, rejected) = producer.join().expect("producer panicked");
            (enqueued, rejected, drained)
        });
        // capacity 2 + 5 pops: the producer can never complete all 1000
        let rejected = rejected.expect("producer must eventually hit the failed window");
        assert_eq!(rejected, enqueued, "rejected entry returns to its caller");
        let mut seen = popped;
        seen.extend(drained);
        let expect: Vec<u64> = (0..enqueued).collect();
        assert_eq!(seen, expect, "every enqueued entry popped or drained exactly once");
    });
}

/// 7. The trainer barrier (train::sync): every crossing elects exactly
/// one leader, under any schedule — the leader slot is what serializes
/// relation-partition reshuffles.
#[test]
fn barrier_elects_exactly_one_leader_per_crossing() {
    model(|| {
        let sync = SyncState::new(3, None);
        let leaders = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    for _ in 0..8 {
                        explore();
                        if sync.wait() {
                            leaders.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(leaders.load(Ordering::Relaxed), 8, "one leader per crossing");
    });
}

/// 8. Per-link ack marks (kvstore::comm): each link's reader applies a
/// push's server-side effect, then acks with a Release increment; the
/// trainer's `pushes_complete` does Acquire loads per link. Once a mark
/// reads complete, the effects of every push it counts must be visible —
/// on *every* link: a fast link's acks must not stand in for a slow one.
#[test]
fn per_link_ack_marks_publish_server_effects() {
    model(|| {
        let acked: Vec<Arc<AtomicU64>> = (0..2).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let effects: Vec<Arc<AtomicU64>> = (0..2).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let mark = [16u64, 16u64];
        std::thread::scope(|s| {
            for link in 0..2 {
                let a = acked[link].clone();
                let e = effects[link].clone();
                s.spawn(move || {
                    for _ in 0..16 {
                        explore();
                        e.fetch_add(1, Ordering::Relaxed); // server applies the push
                        a.fetch_add(1, Ordering::Release); // then the reader acks it
                    }
                });
            }
            loop {
                let complete =
                    mark.iter().zip(&acked).all(|(&m, a)| a.load(Ordering::Acquire) >= m);
                if complete {
                    for (link, e) in effects.iter().enumerate() {
                        assert!(
                            e.load(Ordering::Relaxed) >= 16,
                            "link {link}: mark complete but its pushes are not visible"
                        );
                    }
                    break;
                }
                std::thread::yield_now();
            }
        });
    });
}

/// 9. The serving hot-swap latch (serve::Swap): a publisher replaces the
/// snapshot while readers load it. Every loaded snapshot must be
/// internally uniform — all elements from the same publish — because a
/// publish swaps one `Arc`, never bytes inside a live snapshot. This is
/// the latch half of the serve_tests query-storm guarantee (the other
/// half, per-job snapshot pinning, lives in serve::server).
#[test]
fn swap_readers_see_whole_snapshots_never_torn() {
    model(|| {
        let swap = Arc::new(Swap::new(Arc::new(vec![0u64; 4])));
        std::thread::scope(|s| {
            let w = swap.clone();
            s.spawn(move || {
                for v in 1..=24u64 {
                    let epoch = w.publish(Arc::new(vec![v; 4]));
                    assert_eq!(epoch, v, "publishes are serialized, epochs count them");
                }
            });
            for _ in 0..2 {
                let r = swap.clone();
                s.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..24 {
                        explore();
                        let snap = r.load();
                        assert!(
                            snap.iter().all(|&x| x == snap[0]),
                            "torn snapshot: {snap:?}"
                        );
                        // a reader never travels back in time
                        assert!(snap[0] >= last, "snapshot regressed {last} -> {}", snap[0]);
                        last = snap[0];
                    }
                });
            }
        });
        assert_eq!(swap.epoch(), 24);
    });
}

/// 10. The wait-free staleness probe: `epoch()` is bumped with Release
/// *after* the contents swap (both under the publish lock), and probed
/// with Acquire — so an observed epoch is a floor for what any
/// subsequent load returns, and `load_with_epoch` pairs contents and
/// epoch exactly. A probe that overtook the contents would make a
/// freshness check pass on a stale snapshot.
#[test]
fn swap_epoch_probe_never_overtakes_contents() {
    model(|| {
        let swap = Arc::new(Swap::new(Arc::new(vec![0u64; 2])));
        std::thread::scope(|s| {
            let w = swap.clone();
            s.spawn(move || {
                for v in 1..=24u64 {
                    w.publish(Arc::new(vec![v; 2]));
                }
            });
            let mut last_probe = 0u64;
            for _ in 0..24 {
                explore();
                // paired read: contents and epoch under one latch
                let (snap, epoch) = swap.load_with_epoch();
                assert_eq!(snap[0], epoch, "contents and epoch out of step");
                // independent probe first, load second: the probe is a
                // floor for the later load's contents
                let probe = swap.epoch();
                assert!(probe >= epoch, "epoch went backwards");
                assert!(probe >= last_probe, "probe not monotonic");
                last_probe = probe;
                let later = swap.load();
                assert!(
                    later[0] >= probe,
                    "probe {probe} overtook contents {}",
                    later[0]
                );
            }
        });
    });
}

/// 11. The trace span buffer (obs::trace::SpanBuf): the owning thread
/// appends records — two Relaxed slot stores published by a Release
/// store of `len` — while a drain loads `len` with Acquire
/// (ordering-pairs.toml `trace-buf-len`). Any mid-flight drain must
/// return a consistent prefix: only fully-written records, never a slot
/// whose timestamp landed but whose code did not. Records are encoded so
/// a torn read is detectable (`code == 3 * ts`), and a drain after the
/// writer quiesces must see every event with none dropped.
#[test]
fn trace_buf_drain_reads_full_prefix_never_torn() {
    model(|| {
        let buf = Arc::new(SpanBuf::with_capacity(1, 64));
        std::thread::scope(|s| {
            let w = buf.clone();
            s.spawn(move || {
                for i in 1..=48u64 {
                    explore();
                    assert!(w.push(i, i * 3), "capacity 64 cannot overflow at 48");
                }
            });
            let mut last_len = 0usize;
            for _ in 0..16 {
                explore();
                let events = buf.drain();
                assert!(events.len() >= last_len, "published prefix shrank");
                last_len = events.len();
                for (k, &(ts, code)) in events.iter().enumerate() {
                    let i = k as u64 + 1;
                    assert_eq!((ts, code), (i, 3 * i), "slot {k} torn or reordered");
                }
            }
        });
        let all = buf.drain();
        assert_eq!(all.len(), 48, "quiesced drain lost events");
        assert_eq!(buf.dropped(), 0);
    });
}
