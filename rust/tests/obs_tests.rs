//! Observability integration tests: the non-negotiable contract is that
//! turning tracing/metrics on changes *nothing* about a run — training
//! outputs stay byte-identical across the storage × kernel matrix and in
//! distributed mode — while the artifacts it produces (Chrome trace JSON,
//! registry snapshots in the `Report`) are well-formed and useful.
//!
//! Every test here serializes on one mutex: the trace collector and the
//! metrics registry are process-global (`obs::trace::start()` claims the
//! collector for the whole process), so a concurrently training test
//! would inject its spans — including still-open ones — into another
//! test's session and break the validator.

use std::sync::{Mutex, MutexGuard};

use dglke::api::{ObsSpec, ParallelMode, PipelineSpec, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::{KernelBackend, ModelKind};
use dglke::obs::metrics::{bucket_bounds, bucket_of, Histogram, Snapshot, HISTO_BUCKETS};
use dglke::obs::trace::validate_chrome_trace;
use dglke::runtime::BackendKind;
use dglke::store::{EmbeddingStore, StoreConfig};
use dglke::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

/// One global-obs test at a time; a poisoned lock (a prior test's panic)
/// must not cascade into every later test.
fn serial() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-obs-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic training spec: 1 worker, sync updates, native backend.
fn tiny_spec() -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 40,
        lr: 0.25,
        log_every: 10,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        seed: 5,
        ..Default::default()
    }
}

/// Loss curve + final tables — the full observable training output.
fn train_snapshot(spec: RunSpec) -> (Vec<(u64, f32)>, Vec<f32>, Vec<f32>) {
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    (
        report.loss_curve.clone(),
        session.state().entities.snapshot(),
        session.state().relations.snapshot(),
    )
}

#[test]
fn obs_on_is_byte_identical_across_storage_and_kernels() {
    let _g = serial();
    let dir = tmp_dir("identity");
    // capacity-starved cached mmap so the traced run crosses cache fills,
    // hits, evictions, and write-backs — the counters the registry absorbed
    let cached_mmap = StoreConfig {
        cache_mb: Some(0.004),
        ..StoreConfig::mmap(dir.join("cached").to_string_lossy().into_owned())
    };
    let configs = [
        ("dense", StoreConfig::dense()),
        ("mmap", StoreConfig::mmap(dir.join("mmap").to_string_lossy().into_owned())),
        ("cached mmap", cached_mmap),
    ];
    for (name, storage) in configs {
        for kernels in [KernelBackend::Scalar, KernelBackend::Fused] {
            let tag = format!("{name}/{kernels:?}");
            let mut off = tiny_spec();
            off.storage = storage.clone();
            off.kernels = kernels;
            let mut on = off.clone();
            on.obs = ObsSpec {
                trace: true,
                trace_path: Some(
                    dir.join(format!("trace-{name}-{kernels:?}.json"))
                        .to_string_lossy()
                        .into_owned(),
                ),
                metrics: true,
            };
            let trace_path = on.obs.trace_path.clone().unwrap();
            let (curve_off, ents_off, rels_off) = train_snapshot(off);
            let (curve_on, ents_on, rels_on) = train_snapshot(on);
            assert_eq!(curve_on, curve_off, "{tag}: loss trajectory changed by obs");
            assert_eq!(ents_on, ents_off, "{tag}: entity table changed by obs");
            assert_eq!(rels_on, rels_off, "{tag}: relation table changed by obs");
            // and the traced run left a valid artifact behind
            let text = std::fs::read_to_string(&trace_path).unwrap();
            let check = validate_chrome_trace(&text).unwrap_or_else(|e| {
                panic!("{tag}: invalid trace: {e}");
            });
            assert!(check.events > 0, "{tag}: trace is empty");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn obs_on_is_byte_identical_in_distributed_mode() {
    let _g = serial();
    let dir = tmp_dir("dist");
    let mut off = tiny_spec();
    off.mode = ParallelMode::Distributed {
        machines: 2,
        trainers: 1,
        servers: 1,
        partition: dglke::dist::PartitionStrategy::Metis,
        local_negatives: true,
    };
    off.batches = 20;
    off.log_every = 5;
    off.seed = 3;
    let mut on = off.clone();
    on.obs = ObsSpec {
        trace: true,
        trace_path: Some(dir.join("trace.json").to_string_lossy().into_owned()),
        metrics: true,
    };
    let trace_path = on.obs.trace_path.clone().unwrap();
    let (curve_off, ents_off, rels_off) = train_snapshot(off);
    let (curve_on, ents_on, rels_on) = train_snapshot(on);
    assert_eq!(curve_on, curve_off, "distributed loss trajectory changed by obs");
    assert_eq!(ents_on, ents_off, "distributed entity table changed by obs");
    assert_eq!(rels_on, rels_off, "distributed relation table changed by obs");
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let check = validate_chrome_trace(&text).expect("distributed trace must validate");
    assert!(check.events > 0, "distributed trace is empty");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipelined_trace_shows_prefetch_compute_overlap() {
    let _g = serial();
    let dir = tmp_dir("overlap");
    let mut spec = tiny_spec();
    spec.batches = 60;
    spec.pipeline = PipelineSpec { prefetch: true, depth: 2 };
    spec.obs = ObsSpec {
        trace: true,
        trace_path: Some(dir.join("trace.json").to_string_lossy().into_owned()),
        metrics: false,
    };
    let trace_path = spec.obs.trace_path.clone().unwrap();
    Session::from_spec(spec).unwrap().train().unwrap();

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let check = validate_chrome_trace(&text).expect("pipelined trace must validate");
    // the prefetch thread registered its own span buffer
    assert!(check.threads >= 2, "expected >=2 traced threads, got {}", check.threads);
    // the pipeline's reason to exist, visible in the trace: prefetch
    // spans on one thread overlap compute spans on another
    assert!(
        check.overlap_exists("prefetch.", "train.compute"),
        "no prefetch/compute overlap in {} intervals",
        check.intervals.len()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn metrics_snapshot_rides_the_report_round_trip() {
    let _g = serial();
    let dir = tmp_dir("snapshot");
    let mut spec = tiny_spec();
    // cache-starved mmap exercises the store counters end to end
    spec.storage = StoreConfig {
        cache_mb: Some(0.004),
        ..StoreConfig::mmap(dir.join("t").to_string_lossy().into_owned())
    };
    spec.obs = ObsSpec { trace: false, trace_path: None, metrics: true };
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let snap = report.obs_metrics.as_ref().expect("metrics requested but not attached");
    // the registry saw this run's cache traffic (values are cumulative
    // across the process, so assert presence + floor, not exact counts)
    let hits = snap.counters.get("store.cache.hits").copied().unwrap_or(0);
    let misses = snap.counters.get("store.cache.misses").copied().unwrap_or(0);
    assert!(hits + misses > 0, "cache counters never reached the registry");
    // Report JSON round-trips the snapshot losslessly
    let j = Json::parse(&report.to_json_string()).unwrap();
    let back = Snapshot::from_json(j.get("obs_metrics").unwrap()).unwrap();
    assert_eq!(&back, snap);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn histogram_buckets_contain_their_values() {
    // pure-property test (detached histogram, no global state): every
    // value lands in a bucket whose bounds contain it, the snapshot
    // accounts for every record, and percentile() is a conservative
    // upper bound
    let h = Histogram::detached();
    let mut x: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut max = 0u64;
    let mut sum = 0u64;
    const N: usize = 4096;
    for i in 0..N {
        // xorshift64*, shifted to spread mass across bucket magnitudes
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let v = x >> (i % 60);
        let b = bucket_of(v);
        assert!(b < HISTO_BUCKETS, "bucket index {b} out of range");
        let (lo, hi) = bucket_bounds(b);
        assert!(lo <= v && v <= hi, "{v} outside bucket {b} bounds [{lo}, {hi}]");
        h.record(v);
        max = max.max(v);
        sum = sum.wrapping_add(v);
    }
    let snap = h.snapshot();
    assert_eq!(snap.count, N as u64);
    assert_eq!(snap.sum, sum);
    assert_eq!(snap.buckets.iter().map(|&(_, c)| c).sum::<u64>(), N as u64);
    // bucket list is sparse, ascending, and never zero-count
    for w in snap.buckets.windows(2) {
        assert!(w[0].0 < w[1].0, "buckets out of order");
    }
    assert!(snap.buckets.iter().all(|&(_, c)| c > 0), "zero-count bucket emitted");
    // percentile(1.0) reports the max's bucket upper bound: >= true max
    assert!(snap.percentile(1.0) >= max as f64);
    // percentiles are monotone in p
    let (p50, p95, p99) = (snap.percentile(0.5), snap.percentile(0.95), snap.percentile(0.99));
    assert!(p50 <= p95 && p95 <= p99, "percentiles not monotone: {p50} {p95} {p99}");
}
