//! Prefetch-pipeline equivalence + accounting tests.
//!
//! The pipeline's contract (see `train::prefetch`): with synchronous
//! updates and a single worker, turning prefetch on must not change a
//! single byte of the trained model — on any storage backend. These
//! tests extend PR 2's cross-backend equivalence matrix with the
//! prefetch on/off axis, and pin down the PhaseTimes / TransferLedger
//! accounting the pipeline reports.

use dglke::api::{ParallelMode, PipelineSpec, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::store::{EmbeddingStore, StoreConfig};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-prefetch-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic training spec: 1 worker, sync updates, native backend.
fn spec_with(storage: StoreConfig, prefetch: bool) -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 30,
        lr: 0.25,
        log_every: 5,
        async_update: false,
        pipeline: PipelineSpec { prefetch, depth: 2 },
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        storage,
        seed: 13,
        ..Default::default()
    }
}

fn train_snapshot(spec: RunSpec) -> (Vec<(u64, f32)>, Vec<f32>, Vec<f32>) {
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    (
        report.loss_curve.clone(),
        session.state().entities.snapshot(),
        session.state().relations.snapshot(),
    )
}

#[test]
fn prefetch_is_byte_identical_on_all_backends() {
    let dir = tmp_dir("equiv");
    // the hot-row cache (cache_mb) rides the same equivalence matrix:
    // capacity-starved so the run crosses fills, hits, evictions, and
    // write-backs while staying byte-identical
    let cached_mmap = StoreConfig {
        cache_mb: Some(0.004),
        ..StoreConfig::mmap(dir.join("cached").to_string_lossy().into_owned())
    };
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(3)),
        ("mmap", StoreConfig::mmap(dir.join("mmap").to_string_lossy().into_owned())),
        ("cached mmap", cached_mmap),
    ];
    for (name, storage) in configs {
        let (curve_off, ents_off, rels_off) = train_snapshot(spec_with(storage.clone(), false));
        let (curve_on, ents_on, rels_on) = train_snapshot(spec_with(storage, true));
        assert_eq!(curve_on, curve_off, "{name}: loss trajectory changed by prefetch");
        assert_eq!(ents_on, ents_off, "{name}: entity table changed by prefetch");
        assert_eq!(rels_on, rels_off, "{name}: relation table changed by prefetch");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_is_byte_identical_across_prefetch_matrix() {
    // the acceptance matrix: cached vs uncached mmap, prefetch on and
    // off (sync updates, 1 worker) — all four runs must be byte-identical
    let dir = tmp_dir("cache-matrix");
    let uncached = StoreConfig::mmap(dir.join("plain").to_string_lossy().into_owned());
    let cached = StoreConfig {
        cache_mb: Some(0.004),
        ..StoreConfig::mmap(dir.join("cached").to_string_lossy().into_owned())
    };
    let base = train_snapshot(spec_with(uncached.clone(), false));
    for (tag, storage, prefetch) in [
        ("uncached+prefetch", uncached, true),
        ("cached", cached.clone(), false),
        ("cached+prefetch", cached, true),
    ] {
        let got = train_snapshot(spec_with(storage, prefetch));
        assert_eq!(got.0, base.0, "{tag}: loss trajectory diverged");
        assert_eq!(got.1, base.1, "{tag}: entity table diverged");
        assert_eq!(got.2, base.2, "{tag}: relation table diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefetch_warms_cache_and_credits_hits_as_overlapped() {
    // prefetch + cache composition, GPU ledger view: with the pipeline
    // on, gathers (including their cache hits) are off the critical
    // path; the cache on top must not add critical-path h2d bytes
    let dir = tmp_dir("warm");
    let cached = StoreConfig {
        cache_mb: Some(0.004),
        ..StoreConfig::mmap(dir.join("t").to_string_lossy().into_owned())
    };
    let run = |storage: StoreConfig, prefetch: bool| {
        let mut spec = spec_with(storage, prefetch);
        spec.mode = ParallelMode::Single { workers: 1, gpu: true };
        let mut session = Session::from_spec(spec).unwrap();
        session.train().unwrap()
    };
    // sequential cached run: hits are credited as overlapped instead of
    // h2d, so h2d shrinks and overlapped grows vs the uncached run
    let plain = run(StoreConfig::mmap(dir.join("p").to_string_lossy().into_owned()), false);
    let seq = run(cached.clone(), false);
    assert!(seq.cache_hits > 0, "sequential cached run must hit");
    assert!(
        seq.h2d_bytes < plain.h2d_bytes,
        "cache hits must come off the critical path: {} vs {}",
        seq.h2d_bytes,
        plain.h2d_bytes
    );
    assert!(seq.overlapped_bytes > plain.overlapped_bytes);
    // total gathered volume is conserved between the two ledgers
    assert_eq!(
        seq.h2d_bytes + seq.overlapped_bytes,
        plain.h2d_bytes + plain.overlapped_bytes
    );
    // pipelined cached run: the helper thread's gathers warm the cache
    let pipe = run(cached, true);
    assert!(pipe.cache_hits > 0, "prefetched gathers must warm the cache");
    assert!(pipe.overlapped_bytes > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefetch_depth_does_not_change_results() {
    // deeper pipelines widen the patch window, not the semantics
    let base = train_snapshot(spec_with(StoreConfig::dense(), false));
    for depth in [2, 4, 8] {
        let mut spec = spec_with(StoreConfig::dense(), true);
        spec.pipeline.depth = depth;
        let got = train_snapshot(spec);
        assert_eq!(got.1, base.1, "depth {depth}: entity table diverged");
        assert_eq!(got.0, base.0, "depth {depth}: loss curve diverged");
    }
}

#[test]
fn prefetch_trains_through_multiworker_barriers() {
    // 2 workers + relation partition + frequent barriers: exercises the
    // reshuffle→reset→generation-discard path end to end
    let mut spec = spec_with(StoreConfig::dense(), true);
    spec.mode = ParallelMode::Single { workers: 2, gpu: false };
    spec.batches = 60;
    spec.sync_interval = 10;
    spec.async_update = true; // the production configuration
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    assert_eq!(report.total_batches, 120);
    let first = report.loss_curve.first().unwrap().1;
    assert!(report.final_loss < first, "loss {first} -> {}", report.final_loss);
}

#[test]
fn phases_sum_to_step_time_within_tolerance() {
    // sequential mode: every phase is a disjoint slice of the worker
    // loop, so the sum must stay below wall time and account for the
    // bulk of it
    let mut spec = spec_with(StoreConfig::dense(), false);
    spec.batches = 100;
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let total: f64 = report.phases.iter().map(|(_, s)| *s).sum();
    assert!(total > 0.0, "phases must be recorded");
    assert!(
        total <= report.wall_secs * 1.05,
        "sequential phases ({total:.4}s) cannot exceed wall time ({:.4}s)",
        report.wall_secs
    );
    assert!(
        total >= report.wall_secs * 0.25,
        "phases ({total:.4}s) should cover the bulk of wall time ({:.4}s)",
        report.wall_secs
    );
    // no pipeline phases when prefetch is off
    assert!(report.phases.iter().all(|(p, _)| !p.starts_with("prefetch")));
}

#[test]
fn pipelined_phase_report_separates_overlapped_work() {
    let mut spec = spec_with(StoreConfig::dense(), true);
    spec.batches = 100;
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let get = |name: &str| -> f64 {
        report.phases.iter().find(|(p, _)| p == name).map(|(_, s)| *s).unwrap_or(0.0)
    };
    // the helper thread reports its (overlapped) sample+gather under
    // prefetch.*; the worker's stall shows up as "prefetch"; sampling no
    // longer happens on the worker
    assert!(
        report.phases.iter().any(|(p, _)| p == "prefetch.sample"),
        "missing prefetch.sample in {:?}",
        report.phases
    );
    assert!(report.phases.iter().any(|(p, _)| p == "prefetch.gather"));
    assert!(report.phases.iter().all(|(p, _)| p != "sample"));
    // worker-side critical-path phases are bounded by wall time
    let critical: f64 = ["prefetch", "gather", "compute", "update", "sync"]
        .iter()
        .map(|&p| get(p))
        .sum();
    assert!(
        critical <= report.wall_secs * 1.05,
        "critical-path phases ({critical:.4}s) exceed wall ({:.4}s)",
        report.wall_secs
    );
}

#[test]
fn overlapped_bytes_credited_for_prefetched_gathers_only_when_on() {
    // extends async_overlap_moves_bytes_off_critical_path: with async
    // updates off, the only overlap source is the prefetch pipeline
    let run = |prefetch: bool| {
        let mut spec = spec_with(StoreConfig::dense(), prefetch);
        spec.mode = ParallelMode::Single { workers: 1, gpu: true };
        let mut session = Session::from_spec(spec).unwrap();
        session.train().unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.overlapped_bytes, 0, "nothing overlaps with both knobs off");
    assert!(on.overlapped_bytes > 0, "prefetched gathers must be credited as overlapped");
    // identical sample sequence → the prefetched gather volume equals
    // exactly what the sequential loop billed to the critical path
    assert_eq!(on.overlapped_bytes, off.h2d_bytes);
    // the critical path keeps only the patched rows
    assert!(
        on.h2d_bytes < off.h2d_bytes,
        "pipeline must shrink critical-path h2d: {} vs {}",
        on.h2d_bytes,
        off.h2d_bytes
    );
    // the update-side d2h traffic is untouched by the pipeline
    assert_eq!(on.d2h_bytes, off.d2h_bytes);
}

#[test]
fn ledger_byte_math_matches_shape_formula() {
    // regression for the centralized bytes_moved() helper: with every
    // transfer on the critical path (no async, no prefetch, relations
    // unpinned), h2d per batch is exactly the gathered f32 volume × 4
    let mut spec = spec_with(StoreConfig::dense(), false);
    spec.mode = ParallelMode::Single { workers: 1, gpu: true };
    spec.relation_partition = false;
    let batches = spec.batches as u64;
    let s = spec.shape.unwrap();
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let rel_dim = 16; // TransE: rel_dim == dim
    let per_batch_f32s =
        (s.batch * s.dim) * 2 + s.batch * rel_dim + s.chunks * s.neg_k * s.dim * 2;
    assert_eq!(report.h2d_bytes, batches * (per_batch_f32s as u64) * 4);
}

#[test]
fn prefetch_spec_survives_cli_json_round_trip() {
    let mut spec = spec_with(StoreConfig::sharded(4), true);
    spec.pipeline.depth = 5;
    let parsed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(spec, parsed);
    assert!(parsed.pipeline.prefetch);
    assert_eq!(parsed.pipeline.depth, 5);
}
