//! Prefetch-pipeline equivalence + accounting tests.
//!
//! The pipeline's contract (see `train::prefetch`): with synchronous
//! updates and a single worker, turning prefetch on must not change a
//! single byte of the trained model — on any storage backend. These
//! tests extend PR 2's cross-backend equivalence matrix with the
//! prefetch on/off axis, and pin down the PhaseTimes / TransferLedger
//! accounting the pipeline reports.

use dglke::api::{ParallelMode, PipelineSpec, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::store::{EmbeddingStore, StoreConfig};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-prefetch-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic training spec: 1 worker, sync updates, native backend.
fn spec_with(storage: StoreConfig, prefetch: bool) -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 30,
        lr: 0.25,
        log_every: 5,
        async_update: false,
        pipeline: PipelineSpec { prefetch, depth: 2 },
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        storage,
        seed: 13,
        ..Default::default()
    }
}

fn train_snapshot(spec: RunSpec) -> (Vec<(u64, f32)>, Vec<f32>, Vec<f32>) {
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    (
        report.loss_curve.clone(),
        session.state().entities.snapshot(),
        session.state().relations.snapshot(),
    )
}

#[test]
fn prefetch_is_byte_identical_on_all_backends() {
    let dir = tmp_dir("equiv");
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(3)),
        ("mmap", StoreConfig::mmap(dir.join("mmap").to_string_lossy().into_owned())),
    ];
    for (name, storage) in configs {
        let (curve_off, ents_off, rels_off) = train_snapshot(spec_with(storage.clone(), false));
        let (curve_on, ents_on, rels_on) = train_snapshot(spec_with(storage, true));
        assert_eq!(curve_on, curve_off, "{name}: loss trajectory changed by prefetch");
        assert_eq!(ents_on, ents_off, "{name}: entity table changed by prefetch");
        assert_eq!(rels_on, rels_off, "{name}: relation table changed by prefetch");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefetch_depth_does_not_change_results() {
    // deeper pipelines widen the patch window, not the semantics
    let base = train_snapshot(spec_with(StoreConfig::dense(), false));
    for depth in [2, 4, 8] {
        let mut spec = spec_with(StoreConfig::dense(), true);
        spec.pipeline.depth = depth;
        let got = train_snapshot(spec);
        assert_eq!(got.1, base.1, "depth {depth}: entity table diverged");
        assert_eq!(got.0, base.0, "depth {depth}: loss curve diverged");
    }
}

#[test]
fn prefetch_trains_through_multiworker_barriers() {
    // 2 workers + relation partition + frequent barriers: exercises the
    // reshuffle→reset→generation-discard path end to end
    let mut spec = spec_with(StoreConfig::dense(), true);
    spec.mode = ParallelMode::Single { workers: 2, gpu: false };
    spec.batches = 60;
    spec.sync_interval = 10;
    spec.async_update = true; // the production configuration
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    assert_eq!(report.total_batches, 120);
    let first = report.loss_curve.first().unwrap().1;
    assert!(report.final_loss < first, "loss {first} -> {}", report.final_loss);
}

#[test]
fn phases_sum_to_step_time_within_tolerance() {
    // sequential mode: every phase is a disjoint slice of the worker
    // loop, so the sum must stay below wall time and account for the
    // bulk of it
    let mut spec = spec_with(StoreConfig::dense(), false);
    spec.batches = 100;
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let total: f64 = report.phases.iter().map(|(_, s)| *s).sum();
    assert!(total > 0.0, "phases must be recorded");
    assert!(
        total <= report.wall_secs * 1.05,
        "sequential phases ({total:.4}s) cannot exceed wall time ({:.4}s)",
        report.wall_secs
    );
    assert!(
        total >= report.wall_secs * 0.25,
        "phases ({total:.4}s) should cover the bulk of wall time ({:.4}s)",
        report.wall_secs
    );
    // no pipeline phases when prefetch is off
    assert!(report.phases.iter().all(|(p, _)| !p.starts_with("prefetch")));
}

#[test]
fn pipelined_phase_report_separates_overlapped_work() {
    let mut spec = spec_with(StoreConfig::dense(), true);
    spec.batches = 100;
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let get = |name: &str| -> f64 {
        report.phases.iter().find(|(p, _)| p == name).map(|(_, s)| *s).unwrap_or(0.0)
    };
    // the helper thread reports its (overlapped) sample+gather under
    // prefetch.*; the worker's stall shows up as "prefetch"; sampling no
    // longer happens on the worker
    assert!(
        report.phases.iter().any(|(p, _)| p == "prefetch.sample"),
        "missing prefetch.sample in {:?}",
        report.phases
    );
    assert!(report.phases.iter().any(|(p, _)| p == "prefetch.gather"));
    assert!(report.phases.iter().all(|(p, _)| p != "sample"));
    // worker-side critical-path phases are bounded by wall time
    let critical: f64 = ["prefetch", "gather", "compute", "update", "sync"]
        .iter()
        .map(|&p| get(p))
        .sum();
    assert!(
        critical <= report.wall_secs * 1.05,
        "critical-path phases ({critical:.4}s) exceed wall ({:.4}s)",
        report.wall_secs
    );
}

#[test]
fn overlapped_bytes_credited_for_prefetched_gathers_only_when_on() {
    // extends async_overlap_moves_bytes_off_critical_path: with async
    // updates off, the only overlap source is the prefetch pipeline
    let run = |prefetch: bool| {
        let mut spec = spec_with(StoreConfig::dense(), prefetch);
        spec.mode = ParallelMode::Single { workers: 1, gpu: true };
        let mut session = Session::from_spec(spec).unwrap();
        session.train().unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.overlapped_bytes, 0, "nothing overlaps with both knobs off");
    assert!(on.overlapped_bytes > 0, "prefetched gathers must be credited as overlapped");
    // identical sample sequence → the prefetched gather volume equals
    // exactly what the sequential loop billed to the critical path
    assert_eq!(on.overlapped_bytes, off.h2d_bytes);
    // the critical path keeps only the patched rows
    assert!(
        on.h2d_bytes < off.h2d_bytes,
        "pipeline must shrink critical-path h2d: {} vs {}",
        on.h2d_bytes,
        off.h2d_bytes
    );
    // the update-side d2h traffic is untouched by the pipeline
    assert_eq!(on.d2h_bytes, off.d2h_bytes);
}

#[test]
fn ledger_byte_math_matches_shape_formula() {
    // regression for the centralized bytes_moved() helper: with every
    // transfer on the critical path (no async, no prefetch, relations
    // unpinned), h2d per batch is exactly the gathered f32 volume × 4
    let mut spec = spec_with(StoreConfig::dense(), false);
    spec.mode = ParallelMode::Single { workers: 1, gpu: true };
    spec.relation_partition = false;
    let batches = spec.batches as u64;
    let s = spec.shape.unwrap();
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    let rel_dim = 16; // TransE: rel_dim == dim
    let per_batch_f32s =
        (s.batch * s.dim) * 2 + s.batch * rel_dim + s.chunks * s.neg_k * s.dim * 2;
    assert_eq!(report.h2d_bytes, batches * (per_batch_f32s as u64) * 4);
}

#[test]
fn prefetch_spec_survives_cli_json_round_trip() {
    let mut spec = spec_with(StoreConfig::sharded(4), true);
    spec.pipeline.depth = 5;
    let parsed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(spec, parsed);
    assert!(parsed.pipeline.prefetch);
    assert_eq!(parsed.pipeline.depth, 5);
}
