//! Property-based tests over the coordinator invariants (routing,
//! batching, state management), with hand-rolled generators seeded from
//! the repo RNG (no proptest crate in the vendored set — same idea:
//! random structured inputs, many cases, shrink by rerunning a seed).

use dglke::kg::generator::{generate, GeneratorConfig};
use dglke::kg::{Triplet, TripletStore};
use dglke::kvstore::{KvCluster, TableId};
use dglke::partition::{partition_relations, GraphPartition, MetisConfig, SPLIT};
use dglke::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use dglke::store::{DenseStore, EmbeddingStore, SparseAdagrad, SparseGrads};
use dglke::util::json::Json;
use dglke::util::rng::Rng;

fn random_store(rng: &mut Rng, n_entities: usize, n_relations: usize, n: usize) -> TripletStore {
    let mut s = TripletStore::new(n_entities, n_relations);
    for _ in 0..n {
        let h = rng.gen_index(n_entities) as u32;
        let mut t = rng.gen_index(n_entities) as u32;
        if t == h {
            t = (t + 1) % n_entities as u32;
        }
        s.push(Triplet { head: h, rel: rng.gen_index(n_relations) as u32, tail: t });
    }
    s
}

// ---------------- partitioning invariants ----------------

#[test]
fn prop_graph_partition_total_and_ownership() {
    let mut rng = Rng::seed_from_u64(100);
    for case in 0..8 {
        let store = random_store(&mut rng, 100 + case * 37, 5, 800);
        for k in [2usize, 3, 5] {
            let p = GraphPartition::metis(&store, k, &MetisConfig::default());
            // every entity assigned to a valid machine
            assert!(p.entity_part.iter().all(|&m| (m as usize) < k));
            // triplets follow their head
            for i in 0..store.len() {
                assert_eq!(p.triplet_part[i], p.entity_part[store.heads[i] as usize]);
            }
            // partition sizes sum to totals
            assert_eq!(p.entity_sizes().iter().sum::<u64>() as usize, store.n_entities());
            assert_eq!(p.triplet_sizes().iter().sum::<u64>() as usize, store.len());
        }
    }
}

#[test]
fn prop_metis_no_worse_than_random_on_clustered_graphs() {
    for seed in 0..5 {
        let kg = generate(&GeneratorConfig::tiny(seed));
        let g = dglke::partition::WeightedGraph::from_triplets(&kg.store);
        let m = dglke::partition::metis_partition(&g, 4, &MetisConfig::default());
        let mut rng = Rng::seed_from_u64(seed);
        let r: Vec<u32> = (0..g.n_vertices()).map(|_| rng.gen_index(4) as u32).collect();
        assert!(g.edge_cut(&m) <= g.edge_cut(&r), "seed {seed}");
    }
}

#[test]
fn prop_relation_partition_conservation() {
    let mut rng = Rng::seed_from_u64(200);
    for case in 0..10 {
        let n_rel = 3 + rng.gen_index(60);
        let store = random_store(&mut rng, 50, n_rel, 500 + case * 100);
        let k = 1 + rng.gen_index(6);
        let rp = partition_relations(&store, k, case as u64);
        // every triplet assigned exactly once, to a valid partition
        assert_eq!(rp.triplet_part.len(), store.len());
        assert!(rp.triplet_part.iter().all(|&p| (p as usize) < k));
        assert_eq!(rp.sizes.iter().sum::<u64>() as usize, store.len());
        // non-split relations keep all triplets in one partition
        for i in 0..store.len() {
            let r = store.rels[i] as usize;
            if rp.relation_part[r] != SPLIT {
                assert_eq!(rp.triplet_part[i], rp.relation_part[r]);
            }
        }
    }
}

// ---------------- sampler invariants ----------------

#[test]
fn prop_positive_sampler_is_permutation_per_epoch() {
    let mut rng = Rng::seed_from_u64(300);
    for _ in 0..6 {
        let n = 10 + rng.gen_index(500);
        let idx: Vec<u32> = (0..n as u32).collect();
        let mut s = PositiveSampler::over_indices(idx, rng.next_u64());
        let b = 1 + rng.gen_index(n);
        let mut seen = vec![0u32; n];
        let mut buf = Vec::new();
        let mut drawn = 0;
        while drawn < n {
            let take = b.min(n - drawn);
            s.next_batch(take, &mut buf);
            for &i in &buf {
                seen[i as usize] += 1;
            }
            drawn += take;
        }
        assert!(seen.iter().all(|&c| c == 1), "n={n} b={b}");
    }
}

#[test]
fn prop_uniform_negatives_cover_entity_space() {
    // over many batches, uniform sampling should touch a large fraction of
    // a small entity space (coupon-collector style)
    let store = random_store(&mut Rng::seed_from_u64(1), 64, 2, 256);
    let mut s = NegativeSampler::new(
        NegativeConfig { k: 32, chunk_size: 32, ..Default::default() },
        64,
        9,
    );
    let idx: Vec<u32> = (0..64).collect();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..30 {
        let b = s.assemble(&store, &idx);
        seen.extend(b.neg_tails.iter().copied());
    }
    assert!(seen.len() >= 60, "covered {}", seen.len());
}

#[test]
fn prop_batch_layout_consistent() {
    let mut rng = Rng::seed_from_u64(400);
    for _ in 0..10 {
        let ne = 50 + rng.gen_index(200);
        let store = random_store(&mut rng, ne, 8, 400);
        let k = 1 + rng.gen_index(32);
        let b = 64;
        let cs = [1usize, 2, 4, 8, 16, 32, 64][rng.gen_index(7)];
        let mut s = NegativeSampler::new(
            NegativeConfig { k, chunk_size: cs, degree_frac: 0.3, ..Default::default() },
            ne,
            rng.next_u64(),
        );
        let idx: Vec<u32> = (0..b as u32).collect();
        let batch = s.assemble(&store, &idx);
        assert_eq!(batch.batch_size(), b);
        assert_eq!(batch.chunks, b / cs);
        assert_eq!(batch.neg_heads.len(), batch.chunks * k);
        assert_eq!(batch.neg_tails.len(), batch.chunks * k);
        assert!(batch.neg_heads.iter().all(|&e| (e as usize) < ne));
        // positives match the store rows
        for (j, &i) in idx.iter().enumerate() {
            let t = store.get(i as usize);
            assert_eq!(batch.heads[j], t.head as u64);
            assert_eq!(batch.rels[j], t.rel as u64);
            assert_eq!(batch.tails[j], t.tail as u64);
        }
    }
}

// ---------------- optimizer / gradient state ----------------

#[test]
fn prop_accumulate_preserves_sum() {
    let mut rng = Rng::seed_from_u64(500);
    for _ in 0..10 {
        let dim = 1 + rng.gen_index(8);
        let n = 1 + rng.gen_index(100);
        let mut g = SparseGrads::new(dim);
        let mut expected: std::collections::HashMap<u64, Vec<f64>> = Default::default();
        for _ in 0..n {
            let id = rng.gen_range(10) as u64;
            let row: Vec<f32> = (0..dim).map(|_| rng.gen_normal()).collect();
            g.extend_from(&[id], &row);
            let e = expected.entry(id).or_insert_with(|| vec![0.0; dim]);
            for (a, &b) in e.iter_mut().zip(&row) {
                *a += b as f64;
            }
        }
        let acc = g.accumulate();
        assert_eq!(acc.ids.len(), expected.len());
        for (j, &id) in acc.ids.iter().enumerate() {
            for x in 0..dim {
                let got = acc.rows[j * dim + x] as f64;
                let want = expected[&id][x];
                assert!((got - want).abs() < 1e-3, "id {id} dim {x}");
            }
        }
    }
}

#[test]
fn prop_adagrad_descends_on_convex_problems() {
    let mut rng = Rng::seed_from_u64(600);
    for _ in 0..5 {
        let dim = 1 + rng.gen_index(6);
        let target: Vec<f32> = (0..dim).map(|_| rng.gen_normal()).collect();
        let table = DenseStore::zeros(1, dim);
        let opt = SparseAdagrad::new(1, 1.0);
        for _ in 0..800 {
            let row = table.row(0);
            let grad: Vec<f32> = row.iter().zip(&target).map(|(&x, &t)| 2.0 * (x - t)).collect();
            opt.apply(&table, &[0], &grad);
        }
        for (x, t) in table.row(0).iter().zip(&target) {
            assert!((x - t).abs() < 0.1, "{x} vs {t}");
        }
    }
}

// ---------------- KVStore consistency (random ops vs model) ----------------

#[test]
fn prop_kvstore_matches_in_memory_model() {
    let mut rng = Rng::seed_from_u64(700);
    let n_entities = 40;
    let dim = 4;
    let entity_machine: Vec<u32> = (0..n_entities).map(|_| rng.gen_index(2) as u32).collect();
    let cluster = KvCluster::start(&entity_machine, 6, 2, 2, dim, dim, 0.5, 0.1, 77).unwrap();

    // reference model: same init (id-derived), same AdaGrad
    let model = DenseStore::zeros(n_entities, dim);
    for id in 0..n_entities {
        let mut r = Rng::seed_from_u64(77 ^ ((id as u64).wrapping_mul(2) + 1));
        let row: Vec<f32> = (0..dim).map(|_| r.gen_uniform(-0.1, 0.1)).collect();
        model.set_row(id, &row);
    }
    let model_opt = SparseAdagrad::new(n_entities, 0.5);

    let mut client = cluster.client(0).unwrap();
    for _ in 0..200 {
        if rng.gen_f32() < 0.5 {
            // random push of 1-4 unique ids
            let n = 1 + rng.gen_index(4);
            let ids: Vec<u64> =
                rng.sample_distinct(n_entities, n).into_iter().map(|x| x as u64).collect();
            let rows: Vec<f32> = (0..n * dim).map(|_| rng.gen_normal()).collect();
            client.push(TableId::Entities, &ids, dim, &rows).unwrap();
            model_opt.apply(&model, &ids, &rows);
        } else {
            // random pull must match the model exactly
            let n = 1 + rng.gen_index(6);
            let ids: Vec<u64> = (0..n).map(|_| rng.gen_range(n_entities as u64)).collect();
            let mut out = vec![0f32; n * dim];
            client.pull(TableId::Entities, &ids, dim, &mut out).unwrap();
            for (j, &id) in ids.iter().enumerate() {
                assert_eq!(
                    &out[j * dim..(j + 1) * dim],
                    model.row(id as usize),
                    "divergence at id {id}"
                );
            }
        }
    }
}

#[test]
fn kvstore_survives_malformed_frames() {
    use std::io::Write;
    let entity_machine = vec![0u32; 8];
    let cluster = KvCluster::start(&entity_machine, 2, 1, 1, 4, 4, 0.1, 0.1, 1).unwrap();
    // garbage connection: random bytes then dropped
    {
        let mut s = std::net::TcpStream::connect(cluster.addrs[0]).unwrap();
        s.write_all(&[0xde, 0xad, 0xbe, 0xef, 0x01, 0x02]).unwrap();
        // oversized length prefix
        let mut s2 = std::net::TcpStream::connect(cluster.addrs[0]).unwrap();
        s2.write_all(&u32::MAX.to_le_bytes()).unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    // server still serves valid clients afterwards
    let mut client = cluster.client(0).unwrap();
    let mut out = vec![0f32; 4];
    client.pull(TableId::Entities, &[3], 4, &mut out).unwrap();
    assert!(out.iter().all(|v| v.is_finite()));
}

// ---------------- json fuzz ----------------

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_index(4) } else { rng.gen_index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_f32() < 0.5),
            2 => Json::Num((rng.gen_normal() * 100.0).round() as f64),
            3 => {
                let n = rng.gen_index(8);
                Json::Str((0..n).map(|_| char::from(33 + rng.gen_index(90) as u8)).collect())
            }
            4 => Json::Arr((0..rng.gen_index(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.gen_index(4) {
                    m.insert(format!("k{i}"), gen_value(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::seed_from_u64(800);
    for _ in 0..200 {
        let v = gen_value(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, v, "{text}");
    }
}

// ---------------- hogwild under real contention ----------------

#[test]
fn hogwild_updates_all_land_on_disjoint_rows() {
    let table = std::sync::Arc::new(DenseStore::zeros(256, 8));
    let opt = std::sync::Arc::new(SparseAdagrad::new(256, 1.0));
    dglke::util::threadpool::scoped_map(8, |w| {
        let mut rng = Rng::seed_from_u64(w as u64);
        for _ in 0..200 {
            let id = (w * 32 + rng.gen_index(32)) as u64; // worker-disjoint rows
            let grad: Vec<f32> = (0..8).map(|_| rng.gen_normal()).collect();
            opt.apply(&table, &[id], &grad);
        }
    });
    // every worker's rows moved; no row left NaN/inf
    for row in 0..256 {
        assert!(table.row(row).iter().all(|v| v.is_finite()));
    }
}
