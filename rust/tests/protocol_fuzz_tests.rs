//! Property/fuzz tests for the KVStore wire protocol (PR 6).
//!
//! The framing layer is the one place a remote peer's bytes reach this
//! process before any validation, so it must be total: for ANY byte
//! input, `read_frame`/`split_tag`/decode either return a value or a
//! clean `Err` — never a panic, never an over-allocation, never a read
//! past the buffer. These tests drive the codecs with a seeded RNG
//! (deterministic, reproducible by seed) through the adversarial cases:
//! truncated frames, empty payloads, oversized length prefixes, flipped
//! bytes, and interleaved tagged frames on one stream.
//!
//! The serving request/response codecs (`serve::protocol`) ride the same
//! framing and face the same adversary, so they get the same treatment
//! below: seeded round-trips, byte-flip totality, truncation at every
//! cut, and hostile count prefixes that must be rejected before any
//! allocation.

use dglke::kvstore::protocol::{
    decode_pull, decode_push, encode_pull, encode_push, prepend_tag, read_frame, split_tag,
    write_frame, TableId, OP_TOK, OP_TPULL, OP_TPUSH,
};
use dglke::util::rng::Rng;
use std::io::Cursor;

/// Round-trip: anything written by `write_frame` is read back verbatim.
#[test]
fn frame_roundtrip_arbitrary_payloads() {
    let mut rng = Rng::seed_from_u64(0xF2A3E);
    for _ in 0..200 {
        let n = rng.gen_index(2048);
        let payload: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let op = (rng.next_u32() % 255) as u8;
        let mut wire = Vec::new();
        write_frame(&mut wire, op, &payload).unwrap();
        let (got_op, got_payload) = read_frame(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(got_op, op);
        assert_eq!(got_payload, payload);
    }
}

/// An empty payload is legal (OP_STOP sends one): len counts the opcode.
#[test]
fn empty_payload_frame_roundtrips() {
    let mut wire = Vec::new();
    write_frame(&mut wire, 4, &[]).unwrap();
    assert_eq!(wire, [1, 0, 0, 0, 4], "len=1 counts only the opcode byte");
    let (op, payload) = read_frame(&mut Cursor::new(&wire)).unwrap();
    assert_eq!(op, 4);
    assert!(payload.is_empty());
}

/// Truncating a valid frame at EVERY byte boundary yields Err, not a
/// panic or a short read passed off as success.
#[test]
fn truncated_frames_error_at_every_cut() {
    let mut wire = Vec::new();
    let payload: Vec<u8> = (0u8..64).collect();
    write_frame(&mut wire, 7, &payload).unwrap();
    for cut in 0..wire.len() {
        let r = read_frame(&mut Cursor::new(&wire[..cut]));
        assert!(r.is_err(), "cut at {cut}/{} must error", wire.len());
    }
    // the full buffer still parses
    assert!(read_frame(&mut Cursor::new(&wire)).is_ok());
}

/// Oversized or zero length prefixes are rejected before any allocation:
/// a hostile 1 GiB+ header must not OOM the server.
#[test]
fn hostile_length_prefixes_are_rejected() {
    for len in [0u32, (1 << 30) + 1, u32::MAX] {
        let mut wire = Vec::new();
        wire.extend_from_slice(&len.to_le_bytes());
        wire.extend_from_slice(&[1, 0xAA, 0xBB]);
        let r = read_frame(&mut Cursor::new(&wire));
        assert!(r.is_err(), "length {len} must be rejected");
    }
    // an in-range length whose body never arrives: clean EOF error
    let mut wire = Vec::new();
    wire.extend_from_slice(&1000u32.to_le_bytes());
    wire.push(1);
    assert!(read_frame(&mut Cursor::new(&wire)).is_err(), "EOF before body");
}

/// split_tag: total on arbitrary inputs; exact inverse of prepend_tag.
#[test]
fn tag_split_is_total_and_inverts_prepend() {
    let mut rng = Rng::seed_from_u64(0x7A6);
    for _ in 0..200 {
        let n = rng.gen_index(256);
        let inner: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let tag = rng.next_u32();
        let tagged = prepend_tag(tag, &inner);
        let (got_tag, got_inner) = split_tag(&tagged).unwrap();
        assert_eq!(got_tag, tag);
        assert_eq!(got_inner, &inner[..]);
    }
    // shorter than a tag: clean error, any byte content
    for n in 0..4usize {
        let short: Vec<u8> = vec![0xFF; n];
        assert!(split_tag(&short).is_err(), "{n}-byte payload must be too short");
    }
}

/// Pull/push payload decoders survive random byte flips: every outcome
/// is Ok or Err, and an Ok must round-trip its own re-encoding.
#[test]
fn decoders_are_total_under_byte_flips() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    let slots: Vec<u64> = (0..17).collect();
    let rows: Vec<f32> = (0..17 * 4).map(|i| i as f32 * 0.25).collect();
    let pull = encode_pull(TableId::Entities, &slots);
    let push = encode_push(TableId::Relations, &slots, &rows);
    for _ in 0..500 {
        let mut p = pull.clone();
        let i = rng.gen_index(p.len());
        p[i] ^= (rng.next_u32() % 255 + 1) as u8;
        if let Ok((table, got_slots)) = decode_pull(&p) {
            let re = encode_pull(table, &got_slots);
            assert_eq!(decode_pull(&re).unwrap().1, got_slots);
        }
        let mut q = push.clone();
        let i = rng.gen_index(q.len());
        q[i] ^= (rng.next_u32() % 255 + 1) as u8;
        if let Ok((table, got_slots, got_rows)) = decode_push(&q) {
            let re = encode_push(table, &got_slots, &got_rows);
            let (_, s2, r2) = decode_push(&re).unwrap();
            assert_eq!(s2, got_slots);
            assert_eq!(r2.len(), got_rows.len());
        }
    }
    // truncation at every boundary is also total
    for cut in 0..push.len() {
        let _ = decode_push(&push[..cut]); // must not panic
        let _ = decode_pull(&pull[..cut.min(pull.len())]);
    }
}

/// Many tagged frames interleaved on one stream parse back in order with
/// their tags intact — the invariant the pipelined reader relies on to
/// match responses against its in-flight window.
#[test]
fn interleaved_tagged_frames_keep_order_and_tags() {
    let mut rng = Rng::seed_from_u64(0x51D);
    let mut wire = Vec::new();
    let mut expect = Vec::new();
    for tag in 0..100u32 {
        let kind = rng.gen_index(3);
        let (op, inner) = match kind {
            0 => (OP_TPULL, encode_pull(TableId::Entities, &[tag as u64, 7, 7])),
            1 => {
                let rows: Vec<f32> = (0..8).map(|_| rng.gen_f32()).collect();
                (OP_TPUSH, encode_push(TableId::Relations, &[1, 2], &rows))
            }
            _ => (OP_TOK, vec![rng.next_u32() as u8; rng.gen_index(31)]),
        };
        write_frame(&mut wire, op, &prepend_tag(tag, &inner)).unwrap();
        expect.push((op, tag, inner));
    }
    let mut cursor = Cursor::new(&wire);
    for (op, tag, inner) in expect {
        let (got_op, payload) = read_frame(&mut cursor).unwrap();
        assert_eq!(got_op, op);
        let (got_tag, got_inner) = split_tag(&payload).unwrap();
        assert_eq!(got_tag, tag, "tags must survive interleaving in order");
        assert_eq!(got_inner, &inner[..]);
    }
    assert!(read_frame(&mut cursor).is_err(), "stream fully consumed");
}

mod serve_codec {
    use dglke::serve::protocol::{
        decode_query_batch, decode_reply, encode_query_batch, encode_reply, read_query_batch,
        read_reply, write_query_batch, write_reply, MAX_BATCH, OP_SQUERY,
    };
    use dglke::serve::{Query, TopK};
    use dglke::util::rng::Rng;
    use std::io::Cursor;

    fn arbitrary_queries(rng: &mut Rng, n: usize) -> Vec<Query> {
        (0..n)
            .map(|_| {
                let e = ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64;
                let r = rng.next_u32() as u64;
                if rng.gen_index(2) == 0 {
                    Query::tail(e, r)
                } else {
                    Query::head(e, r)
                }
            })
            .collect()
    }

    /// Round-trip: arbitrary batches (including empty) survive
    /// encode/decode verbatim — sides, full-width u64 ids, and k.
    #[test]
    fn query_batch_roundtrips_arbitrary_batches() {
        let mut rng = Rng::seed_from_u64(0x5E21E);
        for _ in 0..200 {
            let n = rng.gen_index(64);
            let queries = arbitrary_queries(&mut rng, n);
            let k = rng.next_u32();
            let wire = encode_query_batch(k, &queries);
            // [u32 k][u64 n] header + 17 bytes (side tag + two ids) each
            assert_eq!(wire.len(), 12 + n * 17);
            let (got_k, got) = decode_query_batch(&wire).unwrap();
            assert_eq!(got_k, k);
            assert_eq!(got, queries);
        }
        // the empty batch is legal on the wire (servers answer it with an
        // empty reply rather than erroring)
        let wire = encode_query_batch(10, &[]);
        let (k, got) = decode_query_batch(&wire).unwrap();
        assert_eq!((k, got.len()), (10, 0));
    }

    /// Totality under byte flips: every outcome is Ok or Err — no panic,
    /// no over-allocation — and an Ok must round-trip its re-encoding.
    #[test]
    fn query_decoder_is_total_under_byte_flips() {
        let mut rng = Rng::seed_from_u64(0xFACADE);
        let queries = arbitrary_queries(&mut rng, 23);
        let wire = encode_query_batch(5, &queries);
        for _ in 0..500 {
            let mut w = wire.clone();
            let i = rng.gen_index(w.len());
            w[i] ^= (rng.next_u32() % 255 + 1) as u8;
            if let Ok((k, got)) = decode_query_batch(&w) {
                let re = encode_query_batch(k, &got);
                assert_eq!(decode_query_batch(&re).unwrap(), (k, got));
            }
        }
        // truncation at EVERY cut is a clean Err (the full buffer parses)
        for cut in 0..wire.len() {
            assert!(decode_query_batch(&wire[..cut]).is_err(), "cut at {cut}");
        }
        assert!(decode_query_batch(&wire).is_ok());
    }

    /// Hostile count prefixes are rejected before any allocation, and
    /// malformed tails (bad side tag, trailing garbage) are caught.
    #[test]
    fn hostile_query_batches_are_rejected() {
        // count over the hard cap
        let mut wire = encode_query_batch(1, &[]);
        wire[4..12].copy_from_slice(&((MAX_BATCH as u64) + 1).to_le_bytes());
        assert!(decode_query_batch(&wire).is_err(), "over-cap count");
        // count claiming more queries than bytes remain: must error
        // without attempting the n*17-byte allocation
        let mut wire = encode_query_batch(1, &[Query::tail(1, 2)]);
        wire[4..12].copy_from_slice(&(u64::from(u32::MAX)).to_le_bytes());
        assert!(decode_query_batch(&wire).is_err(), "count > remaining bytes");
        // a side tag that is neither 0 nor 1
        let mut wire = encode_query_batch(1, &[Query::tail(1, 2)]);
        wire[12] = 7;
        assert!(decode_query_batch(&wire).is_err(), "bad side tag");
        // trailing bytes after the declared batch
        let mut wire = encode_query_batch(1, &[Query::tail(1, 2)]);
        wire.push(0);
        assert!(decode_query_batch(&wire).is_err(), "trailing garbage");
    }

    /// Reply codec: round-trip, byte-flip totality, truncation at every
    /// cut — ragged per-query result lengths included.
    #[test]
    fn reply_codec_is_total_and_roundtrips() {
        let mut rng = Rng::seed_from_u64(0x2E91);
        for _ in 0..100 {
            let n = rng.gen_index(8);
            let results: Vec<TopK> = (0..n)
                .map(|_| {
                    let k = rng.gen_index(12);
                    TopK {
                        ids: (0..k).map(|_| rng.next_u32() as u64).collect(),
                        scores: (0..k).map(|_| rng.gen_f32()).collect(),
                    }
                })
                .collect();
            let wire = encode_reply(&results);
            let got = decode_reply(&wire).unwrap();
            assert_eq!(got, results);
        }
        let sample = encode_reply(&[
            TopK { ids: vec![3, 1, 4], scores: vec![0.5, 0.25, 0.125] },
            TopK { ids: vec![], scores: vec![] },
        ]);
        for _ in 0..500 {
            let mut w = sample.clone();
            let i = rng.gen_index(w.len());
            w[i] ^= (rng.next_u32() % 255 + 1) as u8;
            if let Ok(got) = decode_reply(&w) {
                assert_eq!(decode_reply(&encode_reply(&got)).unwrap(), got);
            }
        }
        for cut in 0..sample.len() {
            assert!(decode_reply(&sample[..cut]).is_err(), "cut at {cut}");
        }
    }

    /// Stream framing: a request/reply conversation over one stream, and
    /// an opcode mismatch (a reply where a query was expected) errors
    /// instead of misparsing.
    #[test]
    fn stream_helpers_frame_and_check_opcodes() {
        let mut rng = Rng::seed_from_u64(0xC0FFEE);
        let queries = arbitrary_queries(&mut rng, 9);
        let results =
            vec![TopK { ids: vec![1, 2], scores: vec![1.0, 0.5] }; 3];
        let mut wire = Vec::new();
        write_query_batch(&mut wire, 10, &queries).unwrap();
        write_reply(&mut wire, &results).unwrap();
        let mut cursor = Cursor::new(&wire);
        let (k, got_q) = read_query_batch(&mut cursor).unwrap();
        assert_eq!((k, got_q), (10, queries.clone()));
        assert_eq!(read_reply(&mut cursor).unwrap(), results);
        assert!(read_query_batch(&mut cursor).is_err(), "stream consumed");

        // opcode mismatch both ways
        let mut wire = Vec::new();
        write_reply(&mut wire, &results).unwrap();
        assert!(read_query_batch(&mut Cursor::new(&wire)).is_err(), "reply is not a query");
        let mut wire = Vec::new();
        write_query_batch(&mut wire, 1, &queries).unwrap();
        // frame layout is [u32 len][opcode][payload]: byte 4 is the opcode
        assert_eq!(wire[4], OP_SQUERY);
        assert!(read_reply(&mut Cursor::new(&wire)).is_err(), "query is not a reply");
    }
}
