//! Serving subsystem gate (`serve::`): the offline/online parity harness
//! plus checkpoint-rejection regressions and the hot-swap query storm.
//!
//! The core contract: a [`dglke::serve::Snapshot`] answering `(e, r, ?)` /
//! `(?, r, e)` top-k queries over an exported checkpoint must produce
//! **bit-identical** scores — and therefore identical ranks, with the
//! offline tie-break (descending score, ascending id) — to what the
//! offline evaluation pipeline computes from the live session state. The
//! parity matrix covers all three storage backends x scalar/fused kernels
//! x top-k depths {1, 10, vocab}.

use dglke::api::{ParallelMode, RunSpec, Session};
use dglke::eval::full_ranking;
use dglke::models::step::StepShape;
use dglke::models::{EvalScratch, KernelBackend, LossCfg, ModelKind, NativeModel};
use dglke::runtime::BackendKind;
use dglke::serve::{
    CheckpointManifest, Query, ServeConfig, ServeHandle, ServeScratch, Snapshot, SnapshotOptions,
    TopK, FORMAT_VERSION,
};
use dglke::store::{EmbeddingStore, StoreBackendKind, StoreConfig};
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-serve-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Train a small deterministic session on the tiny dataset (200 entities,
/// 8 relations): 1 worker, sync updates, so a given seed always produces
/// the same embeddings.
fn trained_session(storage: StoreConfig, seed: u64) -> Session {
    let spec = RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 30,
        lr: 0.25,
        log_every: 100,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        storage,
        seed,
        ..Default::default()
    };
    let mut session = Session::from_spec(spec).unwrap();
    session.train().unwrap();
    session
}

/// Independent offline reference: gather every candidate row from the live
/// session state, score with the *scalar* kernels (the reference path the
/// fused kernels are parity-tested against), rank with
/// `eval::full_ranking`, take the prefix. Shares no code with
/// `Snapshot::query` beyond the model math itself.
fn offline_topk(session: &Session, q: &Query, k: usize) -> TopK {
    let state = session.state();
    let dim = state.dim;
    let n = state.entities.rows();
    let native = NativeModel::new(session.spec().model, dim, LossCfg::default());
    let mut e_row = vec![0f32; dim];
    state.entities.read_row(q.e as usize, &mut e_row);
    let mut r_row = vec![0f32; state.rel_dim];
    state.relations.read_row(q.r as usize, &mut r_row);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut cand = vec![0f32; n * dim];
    state.entities.gather(&ids, &mut cand);
    let mut scores = vec![0f32; n];
    let mut scratch = EvalScratch::default();
    native.eval_scores_with(
        q.side,
        &e_row,
        &r_row,
        &cand,
        &mut scores,
        KernelBackend::Scalar,
        &mut scratch,
    );
    let order = full_ranking(&scores);
    let k = k.min(n);
    TopK {
        ids: order[..k].iter().map(|&i| i as u64).collect(),
        scores: order[..k].iter().map(|&i| scores[i]).collect(),
    }
}

fn bits(scores: &[f32]) -> Vec<u32> {
    scores.iter().map(|s| s.to_bits()).collect()
}

fn sample_queries(n_entities: u64, n_relations: u64) -> Vec<Query> {
    vec![
        Query::tail(0, 0),
        Query::head(0, 0),
        Query::tail(n_entities - 1, n_relations - 1),
        Query::head(n_entities / 2, n_relations / 2),
        Query::tail(17, 3),
        Query::head(42, 5),
    ]
}

#[test]
fn served_topk_matches_offline_ranks_across_backends_kernels_and_k() {
    let storages = [
        ("dense", StoreConfig { backend: StoreBackendKind::Dense, ..Default::default() }),
        ("sharded", StoreConfig { backend: StoreBackendKind::Sharded, shards: 4, ..Default::default() }),
        ("mmap", StoreConfig { backend: StoreBackendKind::Mmap, ..Default::default() }),
    ];
    for (tag, storage) in storages {
        let session = trained_session(storage, 7);
        let dir = tmp_dir(&format!("parity-{tag}"));
        session.export_embeddings(&dir).unwrap();
        let n = session.state().entities.rows();
        let queries = sample_queries(n as u64, session.dataset().n_relations() as u64);
        for kernels in [KernelBackend::Scalar, KernelBackend::Fused] {
            let snap =
                Snapshot::open_with(&dir, &SnapshotOptions { cache_mb: None, kernels }).unwrap();
            let mut scratch = ServeScratch::default();
            for k in [1usize, 10, n] {
                for q in &queries {
                    let served = snap.query(q, k, &mut scratch).unwrap();
                    let offline = offline_topk(&session, q, k);
                    assert_eq!(
                        served.ids, offline.ids,
                        "rank divergence: storage={tag} kernels={kernels:?} k={k} query={q:?}"
                    );
                    assert_eq!(
                        bits(&served.scores),
                        bits(&offline.scores),
                        "score bits diverge: storage={tag} kernels={kernels:?} k={k} query={q:?}"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn cached_snapshot_preserves_parity() {
    let session = trained_session(StoreConfig::default(), 7);
    let dir = tmp_dir("parity-cached");
    session.export_embeddings(&dir).unwrap();
    let snap = Snapshot::open_with(
        &dir,
        &SnapshotOptions { cache_mb: Some(2.0), kernels: KernelBackend::Fused },
    )
    .unwrap();
    let mut scratch = ServeScratch::default();
    let queries = sample_queries(snap.n_entities() as u64, snap.n_relations() as u64);
    // twice: cold pass fills the hot-row cache, warm pass serves from it
    for pass in 0..2 {
        for q in &queries {
            let served = snap.query(q, 10, &mut scratch).unwrap();
            let offline = offline_topk(&session, q, 10);
            assert_eq!(served.ids, offline.ids, "pass {pass} query {q:?}");
            assert_eq!(bits(&served.scores), bits(&offline.scores), "pass {pass}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chunked_export_round_trips_and_serves_identically() {
    let session = trained_session(StoreConfig::default(), 7);
    let single = tmp_dir("chunked-single");
    let chunked = tmp_dir("chunked-multi");
    session.export_embeddings(&single).unwrap();
    // 64-row chunks: entities (200 rows) split into 4 files
    session.export_embeddings_chunked(&chunked, 64).unwrap();
    assert!(chunked.join("entities.00003.f32").exists());
    assert!(!chunked.join("checkpoint.json").exists(), "chunked exports are manifest-only");

    let a = Snapshot::open(&single).unwrap();
    let b = Snapshot::open(&chunked).unwrap();
    let mut s1 = ServeScratch::default();
    let mut s2 = ServeScratch::default();
    for q in sample_queries(a.n_entities() as u64, a.n_relations() as u64) {
        let ra = a.query(&q, 10, &mut s1).unwrap();
        let rb = b.query(&q, 10, &mut s2).unwrap();
        assert_eq!(ra.ids, rb.ids);
        assert_eq!(bits(&ra.scores), bits(&rb.scores));
    }

    // a fresh session loads the chunked checkpoint back bit-for-bit
    let mut fresh = trained_session(StoreConfig::default(), 999);
    assert_ne!(fresh.state().entities.snapshot(), session.state().entities.snapshot());
    fresh.load_checkpoint(&chunked).unwrap();
    assert_eq!(fresh.state().entities.snapshot(), session.state().entities.snapshot());
    assert_eq!(fresh.state().relations.snapshot(), session.state().relations.snapshot());

    std::fs::remove_dir_all(&single).ok();
    std::fs::remove_dir_all(&chunked).ok();
}

/// Regression: checkpoint loading used to trust whatever `checkpoint.json`
/// said — no version field, no file-size validation — so a truncated or
/// future-format checkpoint would stream garbage into the tables. Each
/// rejection path below must fail *before* any table row is mutated.
#[test]
fn rejected_checkpoints_leave_state_untouched() {
    let session = trained_session(StoreConfig::default(), 7);
    let dir = tmp_dir("reject");
    session.export_embeddings(&dir).unwrap();
    let full_entities = std::fs::read(dir.join("entities.f32")).unwrap();
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();

    let mut victim = trained_session(StoreConfig::default(), 999);
    let before = victim.state().entities.snapshot();

    // 1. truncated table file → rejected by both loaders, no mutation
    std::fs::write(dir.join("entities.f32"), &full_entities[..full_entities.len() - 4]).unwrap();
    let err = victim.load_checkpoint(&dir).unwrap_err();
    assert!(format!("{err:?}").contains("bytes"), "{err:?}");
    assert!(Snapshot::open(&dir).is_err());
    assert_eq!(victim.state().entities.snapshot(), before, "no partial mutation");
    std::fs::write(dir.join("entities.f32"), &full_entities).unwrap();

    // 2. future manifest format version → rejected with the version message
    // the Json writer renders compact: `"format_version":2`
    let tampered = manifest_text.replace(
        &format!("\"format_version\":{FORMAT_VERSION}"),
        "\"format_version\":99",
    );
    assert_ne!(tampered, manifest_text, "replace must hit");
    std::fs::write(dir.join("manifest.json"), &tampered).unwrap();
    let err = victim.load_checkpoint(&dir).unwrap_err();
    assert!(
        format!("{err:?}").contains("unsupported checkpoint format version"),
        "{err:?}"
    );
    assert!(Snapshot::open(&dir).is_err());
    assert_eq!(victim.state().entities.snapshot(), before);

    // 3. tampered vocab hash → rejected (ids would be silently remapped)
    let tampered =
        manifest_text.replace("\"entity_vocab_hash\":\"fnv1a:", "\"entity_vocab_hash\":\"fnv1a:f");
    assert_ne!(tampered, manifest_text, "replace must hit");
    std::fs::write(dir.join("manifest.json"), &tampered).unwrap();
    let err = victim.load_checkpoint(&dir).unwrap_err();
    assert!(format!("{err:?}").contains("vocabulary"), "{err:?}");
    assert_eq!(victim.state().entities.snapshot(), before);
    std::fs::write(dir.join("manifest.json"), &manifest_text).unwrap();

    // 4. deleted chunk file → Snapshot::open and load both reject
    std::fs::remove_file(dir.join("relations.f32")).unwrap();
    assert!(victim.load_checkpoint(&dir).is_err());
    assert!(Snapshot::open(&dir).is_err());
    assert_eq!(victim.state().entities.snapshot(), before);
    std::fs::write(dir.join("relations.f32"), std::fs::read(dir.join("entities.f32")).unwrap())
        .unwrap();
    // (restored with the wrong content/size on purpose: size check fires)
    assert!(Snapshot::open(&dir).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

/// Regression for the legacy (format-1, `checkpoint.json`-only) path: the
/// version field is now required and validated, and file sizes are
/// checked before mutation.
#[test]
fn legacy_checkpoint_version_and_size_validated() {
    let session = trained_session(StoreConfig::default(), 7);
    let dir = tmp_dir("legacy");
    session.export_embeddings(&dir).unwrap();
    // force the legacy path
    std::fs::remove_file(dir.join("manifest.json")).unwrap();
    let meta = std::fs::read_to_string(dir.join("checkpoint.json")).unwrap();

    let mut victim = trained_session(StoreConfig::default(), 999);
    let before = victim.state().entities.snapshot();

    // the untampered legacy checkpoint still loads fine
    victim.load_checkpoint(&dir).unwrap();
    assert_eq!(victim.state().entities.snapshot(), session.state().entities.snapshot());

    // stale/future version numbers are rejected
    for bad in ["0", "2", "99"] {
        let tampered = meta.replace("\"version\":1", &format!("\"version\":{bad}"));
        assert_ne!(tampered, meta, "replace must hit");
        std::fs::write(dir.join("checkpoint.json"), &tampered).unwrap();
        let err = victim.load_checkpoint(&dir).unwrap_err();
        assert!(format!("{err:?}").contains("format version"), "version {bad}: {err:?}");
    }

    // a checkpoint.json with no version field at all is rejected too
    // (BTreeMap key order puts "version" last: `,"version":1}`)
    let no_version = meta.replace(",\"version\":1", "");
    assert_ne!(no_version, meta, "replace must hit");
    std::fs::write(dir.join("checkpoint.json"), &no_version).unwrap();
    let err = victim.load_checkpoint(&dir).unwrap_err();
    assert!(format!("{err:?}").contains("format version"), "{err:?}");
    std::fs::write(dir.join("checkpoint.json"), &meta).unwrap();

    // truncated table rejected BEFORE either table is touched: truncate
    // relations.f32 (loaded second) and verify entities were not mutated
    let mut victim = trained_session(StoreConfig::default(), 999);
    let rels = std::fs::read(dir.join("relations.f32")).unwrap();
    std::fs::write(dir.join("relations.f32"), &rels[..rels.len() - 4]).unwrap();
    let err = victim.load_checkpoint(&dir).unwrap_err();
    assert!(format!("{err:?}").contains("truncated"), "{err:?}");
    assert_eq!(victim.state().entities.snapshot(), before, "entities untouched");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_open_is_fully_validated_and_manifest_readable() {
    let session = trained_session(StoreConfig::default(), 7);
    let dir = tmp_dir("open");
    session.export_embeddings(&dir).unwrap();
    let m = CheckpointManifest::load(&dir).unwrap();
    assert_eq!(m.format_version, FORMAT_VERSION);
    assert_eq!(m.model, ModelKind::TransEL2);
    assert_eq!((m.n_entities, m.n_relations, m.dim), (200, 8, 16));
    m.validate().unwrap();
    m.validate_files(&dir).unwrap();
    // a directory without a manifest is not a servable checkpoint
    let empty = tmp_dir("open-empty");
    assert!(Snapshot::open(&empty).is_err());
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&empty).ok();
}

#[test]
fn serve_pool_matches_sequential_order_and_handles_edges() {
    let session = trained_session(StoreConfig::default(), 7);
    let dir = tmp_dir("pool");
    session.export_embeddings(&dir).unwrap();

    let reference = Snapshot::open(&dir).unwrap();
    let n_e = reference.n_entities() as u64;
    let n_r = reference.n_relations() as u64;
    // 100 queries spread across ids and sides, fanned out as jobs of 7
    // over 3 workers — results must come back in submission order
    let queries: Vec<Query> = (0..100u64)
        .map(|i| {
            let (e, r) = (i * 13 % n_e, i * 5 % n_r);
            if i % 2 == 0 {
                Query::tail(e, r)
            } else {
                Query::head(e, r)
            }
        })
        .collect();
    let mut scratch = ServeScratch::default();
    let expected = reference.query_batch(&queries, 10, &mut scratch).unwrap();

    let served = Snapshot::open(&dir).unwrap();
    let handle =
        ServeHandle::start(served, &ServeConfig { threads: 3, batch: 7, topk: 10 });
    let got = handle.submit(&queries, 10).unwrap();
    assert_eq!(got.len(), expected.len());
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g.ids, e.ids, "query {i} out of order or divergent");
        assert_eq!(bits(&g.scores), bits(&e.scores), "query {i}");
    }
    assert_eq!(handle.served(), 100);
    assert_eq!(handle.epoch(), 0, "no publishes happened");

    // empty batch is a no-op
    assert_eq!(handle.submit(&[], 10).unwrap().len(), 0);
    // an out-of-range query surfaces as an error, not a panic or a hang
    let err = handle.submit(&[Query::tail(n_e, 0)], 10).unwrap_err();
    assert!(format!("{err:?}").contains("out of range"), "{err:?}");
    // the pool still works after a failed job
    assert_eq!(handle.submit(&queries[..5], 10).unwrap().len(), 5);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Hot-swap under a query storm: workers pin one snapshot per job, so
/// every answered batch must equal — in its entirety — either checkpoint
/// A's answers or checkpoint B's answers. A torn mix (some queries
/// answered from A, some from B, within one job) is the bug this test
/// exists to catch; the loom model (`loom_tests.rs` contracts 9–10)
/// checks the same property exhaustively on the latch itself.
#[test]
fn hot_swap_storm_never_serves_torn_answers() {
    let session_a = trained_session(StoreConfig::default(), 7);
    let session_b = trained_session(StoreConfig::default(), 8);
    let dir_a = tmp_dir("swap-a");
    let dir_b = tmp_dir("swap-b");
    session_a.export_embeddings(&dir_a).unwrap();
    session_b.export_embeddings(&dir_b).unwrap();

    let probe = Snapshot::open(&dir_a).unwrap();
    let n_e = probe.n_entities() as u64;
    let n_r = probe.n_relations() as u64;
    let queries = sample_queries(n_e, n_r);

    let mut scratch = ServeScratch::default();
    let expect_a = probe.query_batch(&queries, 10, &mut scratch).unwrap();
    let expect_b = Snapshot::open(&dir_b)
        .unwrap()
        .query_batch(&queries, 10, &mut scratch)
        .unwrap();
    assert_ne!(
        expect_a, expect_b,
        "differently-seeded checkpoints must answer differently for the storm to mean anything"
    );

    // batch > queries.len() ⇒ each submit is exactly one job ⇒ per-job
    // snapshot pinning makes the whole reply all-A or all-B
    let cfg = ServeConfig { threads: 4, batch: 64, topk: 10 };
    let handle = ServeHandle::start(Snapshot::open(&dir_a).unwrap(), &cfg);

    std::thread::scope(|s| {
        let publisher = s.spawn(|| {
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
            for round in 0..20u64 {
                // pace each swap against actual serving progress so every
                // round overlaps live queries: 4 workers keep at most
                // 4 jobs = 24 queries in flight, so a 50-query stride
                // guarantees jobs dequeue on both sides of each publish
                // (storm total is 4 x 50 x 6 = 1200 >= 20 x 50)
                while handle.served() < (round + 1) * 50 {
                    assert!(std::time::Instant::now() < deadline, "storm stalled");
                    std::thread::yield_now();
                }
                let dir = if round % 2 == 0 { &dir_b } else { &dir_a };
                let epoch = handle.publish(Snapshot::open(dir).unwrap());
                assert_eq!(epoch, round + 1, "epochs count publishes");
            }
        });
        let mut storms = Vec::new();
        for _ in 0..4 {
            storms.push(s.spawn(|| {
                let (mut saw_a, mut saw_b) = (false, false);
                for _ in 0..50 {
                    let got = handle.submit(&queries, 10).unwrap();
                    if got == expect_a {
                        saw_a = true;
                    } else if got == expect_b {
                        saw_b = true;
                    } else {
                        panic!("torn answer: neither checkpoint A's nor B's reply");
                    }
                }
                (saw_a, saw_b)
            }));
        }
        publisher.join().unwrap();
        let mut any_a = false;
        let mut any_b = false;
        for t in storms {
            let (a, b) = t.join().unwrap();
            any_a |= a;
            any_b |= b;
        }
        // the storm overlapped the publishes: both answer sets were
        // actually observed (20 alternating publishes across 200 submits)
        assert!(any_a && any_b, "storm never overlapped a swap (saw_a={any_a} saw_b={any_b})");
    });

    assert_eq!(handle.epoch(), 20);
    // after the storm the final snapshot (round 19 published dir_a) serves
    let mut scratch = ServeScratch::default();
    let final_ans = handle.snapshot().query_batch(&queries, 10, &mut scratch).unwrap();
    assert_eq!(final_ans, expect_a);

    handle.shutdown();
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}
