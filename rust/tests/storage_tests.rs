//! Cross-backend storage tests: the three [`EmbeddingStore`] backends
//! (dense / sharded / mmap) must be *observationally identical* — same
//! init, same training trajectory, same checkpoints — differing only in
//! where the bytes live. Plus the budget gate that routes larger-than-RAM
//! runs to the mmap backend.

use dglke::api::{ParallelMode, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::store::{EmbeddingStore, StoreBackendKind, StoreConfig};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-storage-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic training spec: 1 worker, sync updates, native backend.
fn spec_with_storage(storage: StoreConfig) -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 25,
        lr: 0.25,
        log_every: 5,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        storage,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn backends_train_byte_identical() {
    let dir = tmp_dir("identical");
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(3)),
        ("mmap", StoreConfig::mmap(dir.join("mmap").to_string_lossy().into_owned())),
    ];
    let mut results = Vec::new();
    for (name, storage) in configs {
        let mut session = Session::from_spec(spec_with_storage(storage)).unwrap();
        assert_eq!(session.state().entities.backend_name(), name);
        let report = session.train().unwrap();
        results.push((
            name,
            report.loss_curve.clone(),
            session.state().entities.snapshot(),
            session.state().relations.snapshot(),
        ));
    }
    let (_, ref curve0, ref ents0, ref rels0) = results[0];
    for (name, curve, ents, rels) in &results[1..] {
        assert_eq!(curve, curve0, "{name}: loss trajectory differs from dense");
        assert_eq!(ents, ents0, "{name}: entity table differs from dense");
        assert_eq!(rels, rels0, "{name}: relation table differs from dense");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mmap_checkpoint_round_trips_into_dense() {
    let dir = tmp_dir("ckpt");
    let store_dir = dir.join("tables");
    let ckpt_dir = dir.join("checkpoint");

    let mut mmap_session = Session::from_spec(spec_with_storage(StoreConfig::mmap(
        store_dir.to_string_lossy().into_owned(),
    )))
    .unwrap();
    mmap_session.train().unwrap();
    // rows live on disk: nothing table-sized resident, yet the logical
    // table is full-size
    assert_eq!(mmap_session.state().entities.resident_bytes(), 0);
    assert!(mmap_session.state().entities.table_bytes() > 0);
    // export streams from the backing file (no snapshot clone involved)
    mmap_session.export_embeddings(&ckpt_dir).unwrap();

    let mut dense_session = Session::from_spec(spec_with_storage(StoreConfig::dense())).unwrap();
    dense_session.load_checkpoint(&ckpt_dir).unwrap();
    assert_eq!(
        dense_session.state().entities.snapshot(),
        mmap_session.state().entities.snapshot()
    );
    assert_eq!(
        dense_session.state().relations.snapshot(),
        mmap_session.state().relations.snapshot()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn budget_gate_routes_big_tables_to_mmap() {
    // a budget far below the tiny dataset's table bytes: dense must be
    // rejected with an actionable error, mmap must train to completion
    let dir = tmp_dir("budget");
    let mut spec = spec_with_storage(StoreConfig::dense());
    spec.storage.budget_mb = Some(0.001); // ~1 KiB
    let err = Session::from_spec(spec).unwrap_err();
    assert!(err.to_string().contains("mmap"), "unhelpful error: {err}");

    let mut spec = spec_with_storage(StoreConfig::mmap(dir.to_string_lossy().into_owned()));
    spec.storage.budget_mb = Some(0.001);
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    // trains (loss decreases) despite tables exceeding the budget
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(last < first, "loss {first} -> {last}");
    assert!(session.state().entities.table_bytes() > 1024);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn budget_gate_bounds_mmap_cache_residency() {
    // regression for the wholesale mmap exemption: an mmap run is gated
    // on its *cache* residency, so a cache_mb above budget_mb must be
    // rejected while a conforming cache passes
    let dir = tmp_dir("cache-gate");
    let mut spec = spec_with_storage(StoreConfig::mmap(dir.to_string_lossy().into_owned()));
    spec.storage.budget_mb = Some(0.25);
    spec.storage.cache_mb = Some(1.0); // cache > budget: resident set too big
    let err = Session::from_spec(spec).unwrap_err();
    assert!(
        err.to_string().contains("cache"),
        "error must name the cache as the resident set: {err}"
    );

    // cache within budget: builds, trains, and actually caches
    let mut spec = spec_with_storage(StoreConfig::mmap(dir.to_string_lossy().into_owned()));
    spec.storage.budget_mb = Some(0.25);
    spec.storage.cache_mb = Some(0.125);
    let mut session = Session::from_spec(spec).unwrap();
    assert_eq!(session.state().entities.backend_name(), "cached");
    let report = session.train().unwrap();
    assert!(report.cache_hits + report.cache_misses > 0, "cache saw no traffic");
    // the dense/sharded arm of the gate is untouched: a budget below the
    // table bytes still rejects a dense run of the same shape
    let mut spec = spec_with_storage(StoreConfig::dense());
    spec.storage.budget_mb = Some(0.001);
    assert!(Session::from_spec(spec).is_err(), "dense tables exceed ~1 KiB");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cached_mmap_trains_byte_identical_and_reports_hits() {
    // the cache must be semantically invisible: a cache-starved cached
    // run equals the uncached (and dense) run byte for byte, while the
    // Report surfaces nonzero hit counters on the warm table
    let dir = tmp_dir("cached-equiv");
    let mut dense_session = Session::from_spec(spec_with_storage(StoreConfig::dense())).unwrap();
    dense_session.train().unwrap();

    let mut spec =
        spec_with_storage(StoreConfig::mmap(dir.join("cached").to_string_lossy().into_owned()));
    // ~4 KiB against ~14 KiB of tables: capacity-starved, forces the
    // full hit/miss/evict/write-back cycle
    spec.storage.cache_mb = Some(0.004);
    let mut cached_session = Session::from_spec(spec).unwrap();
    assert_eq!(cached_session.state().entities.backend_name(), "cached");
    let report = cached_session.train().unwrap();

    assert_eq!(
        cached_session.state().entities.snapshot(),
        dense_session.state().entities.snapshot(),
        "hot-row cache changed the entity table"
    );
    assert_eq!(
        cached_session.state().relations.snapshot(),
        dense_session.state().relations.snapshot(),
        "hot-row cache changed the relation table"
    );
    // warm-table counters surface in the Report (and its JSON)
    assert!(report.cache_hits > 0, "a training run re-touches rows: hits expected");
    assert!(report.cache_misses > 0);
    assert!(report.cache_evictions > 0, "a starved cache must evict");
    assert!(report.cache_write_backs > 0, "dirty victims must write back");
    let j = dglke::util::json::Json::parse(&report.to_json_string()).unwrap();
    assert!(j.get("cache_hits").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("cache_evictions").unwrap().as_f64().is_some());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cached_mmap_checkpoint_exports_dirty_rows() {
    // write-back cache + streaming export: the checkpoint must include
    // rows still dirty in the cache
    let dir = tmp_dir("cached-ckpt");
    let mut spec =
        spec_with_storage(StoreConfig::mmap(dir.join("tables").to_string_lossy().into_owned()));
    spec.storage.cache_mb = Some(0.05);
    let mut session = Session::from_spec(spec).unwrap();
    session.train().unwrap();
    let ckpt = dir.join("ckpt");
    session.export_embeddings(&ckpt).unwrap();

    let mut dense_session = Session::from_spec(spec_with_storage(StoreConfig::dense())).unwrap();
    dense_session.load_checkpoint(&ckpt).unwrap();
    assert_eq!(
        dense_session.state().entities.snapshot(),
        session.state().entities.snapshot()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sharded_store_flush_and_placement() {
    let spec = spec_with_storage(StoreConfig::sharded(4));
    let session = Session::from_spec(spec).unwrap();
    assert_eq!(session.state().entities.backend_name(), "sharded");
    session.state().entities.flush().unwrap();
    assert_eq!(
        session.state().entities.resident_bytes(),
        session.state().entities.table_bytes()
    );
}

#[test]
fn distributed_session_honors_storage_backend() {
    // server shards are hosted on the spec's backend (sharded here); with
    // a single trainer the run is deterministic, so it must train to the
    // exact same dump as a dense-shard run
    let mk = |storage: StoreConfig| {
        let spec = RunSpec {
            mode: ParallelMode::Distributed {
                machines: 1,
                trainers: 1,
                servers: 1,
                partition: dglke::dist::PartitionStrategy::Metis,
                local_negatives: true,
            },
            batches: 10,
            ..spec_with_storage(storage)
        };
        let mut session = Session::from_spec(spec).unwrap();
        session.train().unwrap();
        session.state().entities.snapshot()
    };
    assert_eq!(mk(StoreConfig::sharded(2)), mk(StoreConfig::dense()));
}

#[test]
fn storage_spec_round_trips_through_cli_json() {
    let mut spec = spec_with_storage(StoreConfig::sharded(5));
    spec.storage.budget_mb = Some(64.0);
    let parsed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(spec, parsed);
    assert_eq!(parsed.storage.backend, StoreBackendKind::Sharded);
    assert_eq!(parsed.storage.shards, 5);
}
