//! Cross-backend storage tests: the three [`EmbeddingStore`] backends
//! (dense / sharded / mmap) must be *observationally identical* — same
//! init, same training trajectory, same checkpoints — differing only in
//! where the bytes live. Plus the budget gate that routes larger-than-RAM
//! runs to the mmap backend.

use dglke::api::{ParallelMode, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::store::{EmbeddingStore, StoreBackendKind, StoreConfig};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dglke-storage-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic training spec: 1 worker, sync updates, native backend.
fn spec_with_storage(storage: StoreConfig) -> RunSpec {
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 25,
        lr: 0.25,
        log_every: 5,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        storage,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn backends_train_byte_identical() {
    let dir = tmp_dir("identical");
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(3)),
        ("mmap", StoreConfig::mmap(dir.join("mmap").to_string_lossy().into_owned())),
    ];
    let mut results = Vec::new();
    for (name, storage) in configs {
        let mut session = Session::from_spec(spec_with_storage(storage)).unwrap();
        assert_eq!(session.state().entities.backend_name(), name);
        let report = session.train().unwrap();
        results.push((
            name,
            report.loss_curve.clone(),
            session.state().entities.snapshot(),
            session.state().relations.snapshot(),
        ));
    }
    let (_, ref curve0, ref ents0, ref rels0) = results[0];
    for (name, curve, ents, rels) in &results[1..] {
        assert_eq!(curve, curve0, "{name}: loss trajectory differs from dense");
        assert_eq!(ents, ents0, "{name}: entity table differs from dense");
        assert_eq!(rels, rels0, "{name}: relation table differs from dense");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mmap_checkpoint_round_trips_into_dense() {
    let dir = tmp_dir("ckpt");
    let store_dir = dir.join("tables");
    let ckpt_dir = dir.join("checkpoint");

    let mut mmap_session = Session::from_spec(spec_with_storage(StoreConfig::mmap(
        store_dir.to_string_lossy().into_owned(),
    )))
    .unwrap();
    mmap_session.train().unwrap();
    // rows live on disk: nothing table-sized resident, yet the logical
    // table is full-size
    assert_eq!(mmap_session.state().entities.resident_bytes(), 0);
    assert!(mmap_session.state().entities.table_bytes() > 0);
    // export streams from the backing file (no snapshot clone involved)
    mmap_session.export_embeddings(&ckpt_dir).unwrap();

    let mut dense_session = Session::from_spec(spec_with_storage(StoreConfig::dense())).unwrap();
    dense_session.load_checkpoint(&ckpt_dir).unwrap();
    assert_eq!(
        dense_session.state().entities.snapshot(),
        mmap_session.state().entities.snapshot()
    );
    assert_eq!(
        dense_session.state().relations.snapshot(),
        mmap_session.state().relations.snapshot()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn budget_gate_routes_big_tables_to_mmap() {
    // a budget far below the tiny dataset's table bytes: dense must be
    // rejected with an actionable error, mmap must train to completion
    let dir = tmp_dir("budget");
    let mut spec = spec_with_storage(StoreConfig::dense());
    spec.storage.budget_mb = Some(0.001); // ~1 KiB
    let err = Session::from_spec(spec).unwrap_err();
    assert!(err.to_string().contains("mmap"), "unhelpful error: {err}");

    let mut spec = spec_with_storage(StoreConfig::mmap(dir.to_string_lossy().into_owned()));
    spec.storage.budget_mb = Some(0.001);
    let mut session = Session::from_spec(spec).unwrap();
    let report = session.train().unwrap();
    // trains (loss decreases) despite tables exceeding the budget
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(last < first, "loss {first} -> {last}");
    assert!(session.state().entities.table_bytes() > 1024);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sharded_store_flush_and_placement() {
    let spec = spec_with_storage(StoreConfig::sharded(4));
    let session = Session::from_spec(spec).unwrap();
    assert_eq!(session.state().entities.backend_name(), "sharded");
    session.state().entities.flush().unwrap();
    assert_eq!(
        session.state().entities.resident_bytes(),
        session.state().entities.table_bytes()
    );
}

#[test]
fn distributed_session_honors_storage_backend() {
    // server shards are hosted on the spec's backend (sharded here); with
    // a single trainer the run is deterministic, so it must train to the
    // exact same dump as a dense-shard run
    let mk = |storage: StoreConfig| {
        let spec = RunSpec {
            mode: ParallelMode::Distributed {
                machines: 1,
                trainers: 1,
                servers: 1,
                partition: dglke::dist::PartitionStrategy::Metis,
                local_negatives: true,
            },
            batches: 10,
            ..spec_with_storage(storage)
        };
        let mut session = Session::from_spec(spec).unwrap();
        session.train().unwrap();
        session.state().entities.snapshot()
    };
    assert_eq!(mk(StoreConfig::sharded(2)), mk(StoreConfig::dense()));
}

#[test]
fn storage_spec_round_trips_through_cli_json() {
    let mut spec = spec_with_storage(StoreConfig::sharded(5));
    spec.storage.budget_mb = Some(64.0);
    let parsed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(spec, parsed);
    assert_eq!(parsed.storage.backend, StoreBackendKind::Sharded);
    assert_eq!(parsed.storage.shards, 5);
}
