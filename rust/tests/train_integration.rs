//! End-to-end training integration: the full coordinator loop driving the
//! AOT XLA artifacts (the production path), multi-worker.

use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind, Manifest};
use dglke::train::worker::ModelState;
use dglke::train::{run_training, TrainConfig};

fn manifest() -> Option<Manifest> {
    if !artifacts::available() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Manifest::load(&artifacts::default_dir()).unwrap())
}

#[test]
fn xla_training_reduces_loss_tiny_artifacts() {
    let Some(manifest) = manifest() else { return };
    let dataset = Dataset::load("tiny", 7).unwrap();
    let cfg = TrainConfig {
        model: ModelKind::TransEL2,
        backend: BackendKind::Xla,
        artifact_tag: "tiny".into(),
        n_workers: 1,
        batches_per_worker: 60,
        lr: 0.25,
        log_every: 10,
        ..Default::default()
    };
    let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
    let stats = run_training(&dataset, &state, Some(&manifest), &cfg).unwrap();
    let first = stats.loss_curve.first().unwrap().1;
    let last = stats.loss_curve.last().unwrap().1;
    assert!(last < first, "loss should fall: {first} -> {last}");
}

#[test]
fn xla_multiworker_training() {
    let Some(manifest) = manifest() else { return };
    let dataset = Dataset::load("tiny", 8).unwrap();
    let cfg = TrainConfig {
        model: ModelKind::DistMult,
        backend: BackendKind::Xla,
        artifact_tag: "tiny".into(),
        n_workers: 2,
        batches_per_worker: 30,
        sync_interval: 10,
        lr: 0.25,
        log_every: 10,
        ..Default::default()
    };
    let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
    let stats = run_training(&dataset, &state, Some(&manifest), &cfg).unwrap();
    assert_eq!(stats.total_batches, 60);
    assert!(stats.mean_loss_tail.is_finite());
}

#[test]
fn native_and_xla_agree_over_training_trajectory() {
    // Same seed, single worker, sync updates: both backends should follow
    // nearly the same loss trajectory (small float divergence allowed —
    // XLA reassociates reductions).
    let Some(manifest) = manifest() else { return };
    let dataset = Dataset::load("tiny", 9).unwrap();
    let mk = |backend: BackendKind| {
        let cfg = TrainConfig {
            model: ModelKind::RotatE,
            backend,
            artifact_tag: "tiny".into(),
            shape: Some(dglke::models::step::StepShape {
                batch: 32,
                chunks: 4,
                neg_k: 16,
                dim: 16,
            }),
            n_workers: 1,
            batches_per_worker: 20,
            async_update: false,
            lr: 0.1,
            log_every: 1,
            seed: 42,
            ..Default::default()
        };
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        run_training(&dataset, &state, Some(&manifest), &cfg).unwrap()
    };
    let nat = mk(BackendKind::Native);
    let xla = mk(BackendKind::Xla);
    assert_eq!(nat.loss_curve.len(), xla.loss_curve.len());
    for ((s1, l1), (s2, l2)) in nat.loss_curve.iter().zip(&xla.loss_curve) {
        assert_eq!(s1, s2);
        assert!(
            (l1 - l2).abs() < 2e-2 * l1.abs().max(1.0),
            "step {s1}: native={l1} xla={l2}"
        );
    }
}
