//! Cross-layer integration test: the AOT-compiled XLA artifacts (L1+L2,
//! Pallas + JAX, lowered to HLO) must numerically match the native Rust
//! mirror (L3) for every model — values AND gradients.
//!
//! This is the strongest correctness signal in the repo: it exercises
//! python/compile/kernels (Pallas), python/compile/model.py (JAX),
//! aot.py (lowering), the HLO-text interchange, the PJRT runtime, and
//! rust/src/models in one assertion.
//!
//! Skips (with a message) when `artifacts/` has not been built.

use dglke::models::step::{StepInputs, StepShape};
use dglke::models::{LossCfg, ModelKind, NativeModel};
use dglke::runtime::{EvalExecutor, Manifest, TrainExecutor, XlaRuntime};
use dglke::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = dglke::runtime::artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gen_normal() * scale).collect()
}

fn assert_close(tag: &str, a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    let mut worst = 0f32;
    let mut worst_i = 0;
    for i in 0..a.len() {
        let err = (a[i] - b[i]).abs() - rtol * b[i].abs();
        if err > worst {
            worst = err;
            worst_i = i;
        }
    }
    assert!(
        worst <= atol,
        "{tag}: mismatch at {worst_i}: {} vs {} (excess {worst})",
        a[worst_i],
        b[worst_i]
    );
}

#[test]
fn train_step_all_models_match() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = XlaRuntime::cpu().unwrap();

    for kind in ModelKind::ALL {
        let art = manifest
            .find_train(kind.name(), "logistic", "tiny")
            .expect("tiny artifact missing — rebuild artifacts");
        let exe = TrainExecutor::new(&rt, art).unwrap();
        let shape = exe.shape;
        let native = NativeModel::new(kind, shape.dim, LossCfg::default());

        let mut rng = Rng::seed_from_u64(kind as u64 * 7 + 1);
        let h = rand_vec(&mut rng, shape.batch * shape.dim, 0.5);
        let r = rand_vec(&mut rng, shape.batch * exe.rel_dim, 0.5);
        let t = rand_vec(&mut rng, shape.batch * shape.dim, 0.5);
        let nh = rand_vec(&mut rng, shape.chunks * shape.neg_k * shape.dim, 0.5);
        let nt = rand_vec(&mut rng, shape.chunks * shape.neg_k * shape.dim, 0.5);
        let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };

        let gx = exe.step(&inp).unwrap();
        let gn = native.train_step(&shape, &inp);

        let name = kind.name();
        assert!(
            (gx.loss - gn.loss).abs() < 1e-4,
            "{name} loss: xla={} native={}",
            gx.loss,
            gn.loss
        );
        assert_close(&format!("{name} d_h"), &gx.d_h, &gn.d_h, 1e-4, 1e-3);
        assert_close(&format!("{name} d_r"), &gx.d_r, &gn.d_r, 1e-4, 1e-3);
        assert_close(&format!("{name} d_t"), &gx.d_t, &gn.d_t, 1e-4, 1e-3);
        assert_close(&format!("{name} d_neg_h"), &gx.d_neg_h, &gn.d_neg_h, 1e-4, 1e-3);
        assert_close(&format!("{name} d_neg_t"), &gx.d_neg_t, &gn.d_neg_t, 1e-4, 1e-3);
    }
}

#[test]
fn eval_scores_all_models_match() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = XlaRuntime::cpu().unwrap();

    for kind in ModelKind::ALL {
        for side in ["tail", "head"] {
            let art = manifest.find_eval(kind.name(), side, "tiny").unwrap();
            let exe = EvalExecutor::new(&rt, art).unwrap();
            let native = NativeModel::new(kind, exe.dim, LossCfg::default());

            let mut rng = Rng::seed_from_u64(kind as u64 * 13 + 5);
            let e = rand_vec(&mut rng, exe.m * exe.dim, 0.5);
            let r = rand_vec(&mut rng, exe.m * exe.rel_dim, 0.5);
            let cand = rand_vec(&mut rng, exe.cands * exe.dim, 0.5);

            let sx = exe.scores(&e, &r, &cand).unwrap();
            let mut sn = vec![0f32; exe.m * exe.cands];
            let eval_side = if side == "tail" {
                dglke::models::EvalSide::Tail
            } else {
                dglke::models::EvalSide::Head
            };
            native.eval_scores(eval_side, &e, &r, &cand, &mut sn);
            assert_close(&format!("{} eval_{side}", kind.name()), &sx, &sn, 1e-4, 1e-3);
        }
    }
}

#[test]
fn deterministic_across_executions() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = XlaRuntime::cpu().unwrap();
    let art = manifest.find_train("distmult", "logistic", "tiny").unwrap();
    let exe = TrainExecutor::new(&rt, art).unwrap();
    let shape = exe.shape;
    let mut rng = Rng::seed_from_u64(3);
    let h = rand_vec(&mut rng, shape.batch * shape.dim, 0.5);
    let r = rand_vec(&mut rng, shape.batch * exe.rel_dim, 0.5);
    let t = rand_vec(&mut rng, shape.batch * shape.dim, 0.5);
    let nh = rand_vec(&mut rng, shape.chunks * shape.neg_k * shape.dim, 0.5);
    let nt = rand_vec(&mut rng, shape.chunks * shape.neg_k * shape.dim, 0.5);
    let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
    let a = exe.step(&inp).unwrap();
    let b = exe.step(&inp).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.d_h, b.d_h);
}
