//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment for this repository has no access to crates.io, so
//! this shim provides the (small) subset of anyhow's API the codebase uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain;
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for any
//!   `std::error::Error` *or* an `Error` itself) and on `Option`.
//!
//! Like the real anyhow, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` impl and the
//! dual `Context` impls coherent.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value: a message plus a chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) context; later entries are
    /// successively deeper causes.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod ext {
    /// Anything `.context()` can turn into an [`crate::Error`]. Implemented
    /// for all std errors and for `Error` itself; the two impls are coherent
    /// because `Error` does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| format!("reading {}", "/definitely/not/a/file"))?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().starts_with("reading /definitely"));
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("Condition failed"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let err = r.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer");
        assert_eq!(err.chain().collect::<Vec<_>>(), vec!["outer", "inner"]);
    }
}
