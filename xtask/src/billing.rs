//! Ledger-billing completeness pass.
//!
//! The byte-provenance reports (NetLedger / TransferLedger, docs/
//! DISTRIBUTED.md) are only honest if every embedding-table access on a
//! billed path actually reaches a billing wrapper. This pass enumerates
//! `read_row/gather/set_row/update_row/set_rows/pull_all` call sites —
//! plus `.pull(`/`.push(` whose first argument is a `TableId` (the KV
//! client API; bare `Vec::push` is not an access) — inside `train/`,
//! `dist.rs`, `kvstore/`, and `serve/`, and requires each to be one of:
//!
//! * **callee-billed** — every crate-local def of the called method is
//!   billing-reachable (e.g. `KvClient::pull` bills internally, so any
//!   `.pull(TableId::..)` call is covered);
//! * **context-billed** — the enclosing fn touches a ledger itself, is
//!   (transitively) called by one that does, or (transitively) calls
//!   into one (the `run_sequential` -> `bill_gather` shape);
//! * **allowed** — `lint:allow(ledger-billing)` with a one-line reason
//!   (snapshot serving and checkpoint load are deliberately unbilled).
//!
//! The reachability is the conservative crate-local call graph — an
//! unresolved call contributes nothing, so a genuinely new unbilled
//! path shows up as a violation rather than vanishing into ambiguity.

use crate::callgraph::{CallGraph, FnRef};
use crate::lexer::{FileLex, Kind};
use std::collections::BTreeSet;

pub const BILLING: &str = "ledger-billing";

/// Methods that move embedding bytes whenever they appear in scope.
const ACCESS_ALWAYS: &[&str] =
    &["read_row", "gather", "set_row", "update_row", "set_rows", "pull_all"];
/// Methods that move bytes only as the KV client API (first arg TableId).
const ACCESS_TABLEID: &[&str] = &["pull", "push"];
/// Identifiers whose presence marks a fn as billing-aware.
const BILL_MARKS: &[&str] =
    &["bill_gather", "bytes_moved", "NetLedger", "TransferLedger", "ledger"];

fn in_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/train/")
        || rel.starts_with("rust/src/kvstore/")
        || rel.starts_with("rust/src/serve/")
        || rel == "rust/src/dist.rs"
}

pub fn check(files: &[FileLex], g: &CallGraph, out: &mut Vec<String>) {
    // fns whose body touches a ledger directly
    let mut direct: BTreeSet<FnRef> = BTreeSet::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.fns.iter().enumerate() {
            let body = &f.toks[d.body_start..d.end.min(f.toks.len())];
            if body.iter().any(|t| t.kind == Kind::Id && BILL_MARKS.contains(&t.text.as_str())) {
                direct.insert((fi, di));
            }
        }
    }
    // billing-reachable: bills directly, calls into billing (the wrapper
    // shape), or is called from billing (the helper shape)
    let closed = g.callers_closure(&direct);
    let desc = g.descendants(&direct);

    for (fi, f) in files.iter().enumerate() {
        if !in_scope(&f.rel) {
            continue;
        }
        let toks = &f.toks;
        for i in 0..toks.len() {
            if !toks[i].is(".")
                || i + 2 >= toks.len()
                || toks[i + 1].kind != Kind::Id
                || !toks[i + 2].is("(")
            {
                continue;
            }
            let name = toks[i + 1].text.as_str();
            let is_access = ACCESS_ALWAYS.contains(&name)
                || (ACCESS_TABLEID.contains(&name)
                    && toks.get(i + 3).is_some_and(|t| t.is_id("TableId")));
            if !is_access {
                continue;
            }
            let line = toks[i].line;
            if f.has_allow(line, BILLING) {
                continue;
            }
            // callee-billed: every crate def of this method bills
            let callee_ok = g
                .defs
                .get(name)
                .is_some_and(|defs| !defs.is_empty() && defs.iter().all(|r| closed.contains(r)));
            // context-billed: the enclosing fn is billing-reachable
            let ctx_ok = f.enclosing_fn(i).is_some_and(|d| {
                let key = (fi, f.fns.iter().position(|x| std::ptr::eq(x, d)).unwrap());
                direct.contains(&key) || closed.contains(&key) || desc.contains(&key)
            });
            if !callee_ok && !ctx_ok {
                out.push(format!(
                    "{}:{line}: [{BILLING}] `.{name}(` is not reachable from a billing wrapper \
                     (bill_gather / bytes_moved / NetLedger) — bill the bytes it moves, or \
                     lint:allow(ledger-billing) with a one-line reason",
                    f.rel
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(srcs: &[(&str, &str)]) -> Vec<String> {
        let files: Vec<FileLex> =
            srcs.iter().map(|(rel, s)| FileLex::from_source(rel, s)).collect();
        let g = CallGraph::build(&files);
        let mut out = Vec::new();
        check(&files, &g, &mut out);
        out
    }

    #[test]
    fn unbilled_gather_fires() {
        let src = "fn rogue(store: &S, ids: &[u64], buf: &mut [f32]) { store.gather(ids, buf); }";
        let out = run(&[("rust/src/train/rogue.rs", src)]);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("ledger-billing") && out[0].contains(".gather("), "{out:?}");
    }

    #[test]
    fn billing_fn_and_its_helpers_are_covered() {
        // the run_sequential shape: gather + bill_gather in one fn, and
        // a helper the billing fn calls is covered transitively
        let src = "fn run(store: &S, ids: &[u64], buf: &mut [f32], ctx: &Ctx) {\n\
                     store.gather(ids, buf);\n\
                     ctx.bill_gather(ids.len());\n\
                     helper(store, ids, buf);\n\
                   }\n\
                   fn helper(store: &S, ids: &[u64], buf: &mut [f32]) { store.gather(ids, buf); }";
        let out = run(&[("rust/src/train/ok.rs", src)]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn callee_that_bills_covers_its_callers() {
        // KvClient::pull bills internally; `.pull(TableId::..)` anywhere
        // in scope is therefore covered even in a non-billing fn
        let kv = "impl KvClient { pub fn pull(&self, t: TableId, ids: &[u64], buf: &mut [f32]) {\n\
                    self.ledger.add(ids.len());\n\
                  } }";
        let user = "fn plain(c: &KvClient, ids: &[u64], buf: &mut [f32]) {\n\
                      c.pull(TableId::Entities, ids, buf);\n\
                    }";
        let out = run(&[("rust/src/kvstore/client.rs", kv), ("rust/src/dist.rs", user)]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn vec_push_is_not_a_kv_access() {
        let src = "fn collect(v: &mut Vec<u64>) { v.push(1); }";
        let out = run(&[("rust/src/train/x.rs", src)]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn out_of_scope_files_are_ignored_and_allow_escapes() {
        let src = "fn free(store: &S, ids: &[u64], buf: &mut [f32]) { store.gather(ids, buf); }";
        let out = run(&[("rust/src/eval/x.rs", src)]);
        assert!(out.is_empty(), "{out:?}");
        let allowed = "fn free(store: &S, ids: &[u64], buf: &mut [f32]) {\n\
                       // lint:allow(ledger-billing) — read-only serving, no training ledger\n\
                       store.gather(ids, buf); }";
        let out = run(&[("rust/src/serve/x.rs", allowed)]);
        assert!(out.is_empty(), "{out:?}");
    }
}
