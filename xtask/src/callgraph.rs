//! Crate-local call graph over the lexed token streams.
//!
//! Resolution is deliberately conservative — a wrong edge in the
//! lock-order pass is a build-breaking false positive, so a call is only
//! resolved when the target is unambiguous:
//!
//! * `self.name(..)`  -> defs named `name` in the same file;
//! * `Type::name(..)` -> defs named `name` in an `impl Type`, falling
//!   back to a crate-wide unique def;
//! * bare `name(..)`  -> same-file defs, else a crate-wide unique def
//!   with a non-generic name;
//! * `expr.name(..)`  -> a crate-wide unique def, and only when `name`
//!   is not std/container vocabulary (`len`, `push`, `read`, ...) — a
//!   "unique" crate def of `len` says nothing about `vec.len()`.
//!
//! Unresolved calls simply contribute no edges; the passes that consume
//! the graph document this best-effort propagation.

use crate::lexer::{is_keyword, FileLex, Kind};
use std::collections::{BTreeMap, BTreeSet};

/// Method names too generic to resolve by crate-wide uniqueness.
const COMMON_METHODS: &[&str] = &[
    "new", "len", "is_empty", "push", "pop", "insert", "remove", "get", "clear", "drain", "iter",
    "next", "read", "write", "lock", "flush", "join", "clone", "drop", "open", "create", "send",
    "recv", "close", "start", "run", "load", "store", "finish", "wait", "contains", "set", "fail",
    "reset", "init", "build", "default",
];

/// (file index, fn index) — a function definition in the crate.
pub type FnRef = (usize, usize);

pub struct CallGraph {
    /// fn name -> every def with that name
    pub defs: BTreeMap<String, Vec<FnRef>>,
    /// resolved call edges per fn
    pub calls: BTreeMap<FnRef, BTreeSet<FnRef>>,
}

impl CallGraph {
    pub fn build(files: &[FileLex]) -> CallGraph {
        let mut defs: BTreeMap<String, Vec<FnRef>> = BTreeMap::new();
        for (fi, f) in files.iter().enumerate() {
            for (di, d) in f.fns.iter().enumerate() {
                defs.entry(d.name.clone()).or_default().push((fi, di));
            }
        }
        let mut g = CallGraph { defs, calls: BTreeMap::new() };
        for (fi, f) in files.iter().enumerate() {
            for (di, d) in f.fns.iter().enumerate() {
                let mut out = BTreeSet::new();
                let toks = &f.toks;
                for i in d.body_start..d.end.min(toks.len()) {
                    if toks[i].kind != Kind::Id
                        || is_keyword(&toks[i].text)
                        || i + 1 >= toks.len()
                        || !toks[i + 1].is("(")
                    {
                        continue;
                    }
                    out.extend(g.resolve(files, fi, toks, i));
                }
                out.remove(&(fi, di)); // self-recursion adds nothing
                g.calls.insert((fi, di), out);
            }
        }
        g
    }

    /// Resolve the call whose name ident is at token `i` (followed by `(`).
    pub fn resolve(
        &self,
        files: &[FileLex],
        fi: usize,
        toks: &[crate::lexer::Tok],
        i: usize,
    ) -> Vec<FnRef> {
        let name = &toks[i].text;
        let Some(cands) = self.defs.get(name) else {
            return Vec::new();
        };
        let prev = if i >= 1 { toks[i - 1].text.as_str() } else { "" };
        let prev2 = if i >= 2 { toks[i - 2].text.as_str() } else { "" };
        if prev == "." {
            // `self.name(` — receiver is plain `self`, not `x.self_field.`
            let plain_self = prev2 == "self" && (i < 3 || !toks[i - 3].is("."));
            if plain_self {
                return cands.iter().copied().filter(|&(cf, _)| cf == fi).collect();
            }
            if COMMON_METHODS.contains(&name.as_str()) {
                return Vec::new();
            }
            return if cands.len() == 1 { cands.clone() } else { Vec::new() };
        }
        if prev == ":" && prev2 == ":" {
            let ty = if i >= 3 { toks[i - 3].text.as_str() } else { "" };
            let by_ty: Vec<FnRef> = cands
                .iter()
                .copied()
                .filter(|&(cf, cd)| files[cf].fns[cd].self_type.as_deref() == Some(ty))
                .collect();
            if !by_ty.is_empty() {
                return by_ty;
            }
            return if cands.len() == 1 { cands.clone() } else { Vec::new() };
        }
        let same: Vec<FnRef> = cands.iter().copied().filter(|&(cf, _)| cf == fi).collect();
        if !same.is_empty() {
            return same;
        }
        if COMMON_METHODS.contains(&name.as_str()) {
            return Vec::new();
        }
        if cands.len() == 1 { cands.clone() } else { Vec::new() }
    }

    /// Propagate per-fn facts to a transitive closure over call edges:
    /// start from `seed(fn)` and union callees' sets until fixpoint.
    pub fn propagate(
        &self,
        mut sets: BTreeMap<FnRef, BTreeSet<String>>,
    ) -> BTreeMap<FnRef, BTreeSet<String>> {
        loop {
            let mut changed = false;
            let keys: Vec<FnRef> = sets.keys().copied().collect();
            for k in keys {
                let mut add = BTreeSet::new();
                for callee in self.calls.get(&k).into_iter().flatten() {
                    if let Some(s) = sets.get(callee) {
                        add.extend(s.iter().cloned());
                    }
                }
                let cur = sets.entry(k).or_default();
                let before = cur.len();
                cur.extend(add);
                changed |= cur.len() != before;
            }
            if !changed {
                return sets;
            }
        }
    }

    /// Every fn transitively *called by* any fn in `roots`.
    pub fn descendants(&self, roots: &BTreeSet<FnRef>) -> BTreeSet<FnRef> {
        let mut seen = BTreeSet::new();
        let mut work: Vec<FnRef> = roots.iter().copied().collect();
        while let Some(k) = work.pop() {
            for &c in self.calls.get(&k).into_iter().flatten() {
                if seen.insert(c) {
                    work.push(c);
                }
            }
        }
        seen
    }

    /// Fns from which some fn in `targets` is reachable *downward* —
    /// i.e. `targets` plus every fn that (transitively) calls into one.
    pub fn callers_closure(&self, targets: &BTreeSet<FnRef>) -> BTreeSet<FnRef> {
        let mut closed = targets.clone();
        loop {
            let mut changed = false;
            for (k, callees) in &self.calls {
                if !closed.contains(k) && callees.iter().any(|c| closed.contains(c)) {
                    closed.insert(*k);
                    changed = true;
                }
            }
            if !changed {
                return closed;
            }
        }
    }
}

/// True when the fn's signature mentions a `*Guard` type: callers of
/// such a helper hold a live guard (the `lock_state` / `lock_current`
/// pattern); calls to any other lock-acquiring fn release before
/// returning.
pub fn is_guard_returning(f: &FileLex, d: &crate::lexer::FnDef) -> bool {
    f.toks[d.start..d.body_start]
        .iter()
        .any(|t| t.kind == Kind::Id && t.text.contains("Guard"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::FileLex;

    fn lexed(srcs: &[(&str, &str)]) -> Vec<FileLex> {
        srcs.iter().map(|(rel, s)| FileLex::from_source(rel, s)).collect()
    }

    #[test]
    fn resolves_self_path_and_unique_calls() {
        let files = lexed(&[
            (
                "rust/src/a.rs",
                "impl A { fn top(&self) { self.helper(); B::other(); distinctive(1); } \
                 fn helper(&self) {} }",
            ),
            ("rust/src/b.rs", "impl B { fn other() {} }\nfn distinctive(x: u8) {}"),
        ]);
        let g = CallGraph::build(&files);
        let top = (0usize, 0usize);
        let callees = &g.calls[&top];
        assert!(callees.contains(&(0, 1)), "self.helper -> same-file def");
        assert!(callees.contains(&(1, 0)), "B::other -> impl B def");
        assert!(callees.contains(&(1, 1)), "bare unique cross-file call");
    }

    #[test]
    fn generic_method_names_do_not_resolve_by_uniqueness() {
        // `win.len()` must NOT resolve to the crate's only `len` def —
        // the receiver is almost always a std container.
        let files = lexed(&[
            ("rust/src/a.rs", "fn user(v: &[u8], w: &W) { v.len(); w.ambiguous(); }"),
            ("rust/src/w.rs", "impl W { fn len(&self) {} fn ambiguous(&self) {} }"),
            ("rust/src/x.rs", "impl X { fn ambiguous(&self) {} }"),
        ]);
        let g = CallGraph::build(&files);
        let callees = &g.calls[&(0, 0)];
        assert!(!callees.contains(&(1, 0)), "len is std vocabulary");
        assert!(!callees.contains(&(1, 1)), "two `ambiguous` defs: unresolved");
        assert!(!callees.contains(&(2, 0)));
    }

    #[test]
    fn propagation_reaches_fixpoint_through_chains() {
        let files = lexed(&[(
            "rust/src/a.rs",
            "fn leaf() {}\nfn mid() { leaf(); }\nfn top() { mid(); }",
        )]);
        let g = CallGraph::build(&files);
        let mut seed: BTreeMap<FnRef, BTreeSet<String>> = BTreeMap::new();
        for k in g.calls.keys() {
            seed.insert(*k, BTreeSet::new());
        }
        seed.get_mut(&(0, 0)).unwrap().insert("fact".to_string());
        let out = g.propagate(seed);
        assert!(out[&(0, 2)].contains("fact"), "top inherits leaf's fact via mid");
    }

    #[test]
    fn guard_returning_detection() {
        let files = lexed(&[(
            "rust/src/a.rs",
            "impl A { fn lock_state(&self) -> MutexGuard<'_, u8> { self.m.lock() } \
             fn plain(&self) -> u8 { 0 } }",
        )]);
        assert!(is_guard_returning(&files[0], &files[0].fns[0]));
        assert!(!is_guard_returning(&files[0], &files[0].fns[1]));
    }
}
