//! Minimal TOML-subset parsers for the four checked-in manifests
//! (`unsafe-budget.toml`, `relaxed-allowlist.toml`, `lock-order.toml`,
//! `ordering-pairs.toml`). No dependencies; the supported grammar is
//! exactly what the manifests use: comments, `[section]`, `[[array]]`
//! tables, and `key = <int | "string" | ["a", "b"]>` (string arrays may
//! span lines).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(usize),
    Str(String),
    List(Vec<String>),
}

#[derive(Debug, Clone)]
pub struct Table {
    /// header as written, e.g. `files`, `class`, `pair.applied-stamp`
    pub name: String,
    /// true for `[[name]]` array-of-tables entries
    pub is_array: bool,
    pub entries: Vec<(String, Value)>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn get_str(&self, key: &str, origin: &str) -> Result<String, String> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s.clone()),
            _ => Err(format!("{origin}: [{}] missing string key `{key}`", self.name)),
        }
    }

    pub fn get_list(&self, key: &str, origin: &str) -> Result<Vec<String>, String> {
        match self.get(key) {
            Some(Value::List(v)) => Ok(v.clone()),
            _ => Err(format!("{origin}: [{}] missing list key `{key}`", self.name)),
        }
    }
}

fn unquote(s: &str, origin: &str, ln: usize) -> Result<String, String> {
    let s = s.trim();
    if s.len() >= 2 && s.starts_with('"') && s.ends_with('"') {
        Ok(s[1..s.len() - 1].to_string())
    } else {
        Err(format!("{origin}:{ln}: expected a double-quoted string, got `{s}`"))
    }
}

/// Strip a trailing `# comment` that is outside any string literal.
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse the manifest into a flat list of tables in file order.
pub fn parse(text: &str, origin: &str) -> Result<Vec<Table>, String> {
    let mut tables: Vec<Table> = Vec::new();
    let mut lines = text.lines().enumerate().peekable();
    while let Some((idx, raw)) = lines.next() {
        let ln = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix("[[") {
            let name = header
                .strip_suffix("]]")
                .ok_or_else(|| format!("{origin}:{ln}: malformed [[table]] header"))?;
            tables.push(Table {
                name: name.trim().to_string(),
                is_array: true,
                entries: Vec::new(),
            });
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let name = header
                .strip_suffix(']')
                .ok_or_else(|| format!("{origin}:{ln}: malformed [table] header"))?;
            tables.push(Table {
                name: name.trim().to_string(),
                is_array: false,
                entries: Vec::new(),
            });
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("{origin}:{ln}: expected `key = value`"))?;
        let key = {
            let k = key.trim();
            if k.starts_with('"') {
                unquote(k, origin, ln)?
            } else {
                k.to_string()
            }
        };
        let mut val = val.trim().to_string();
        // multi-line arrays: consume until the closing `]`
        if val.starts_with('[') && !val.ends_with(']') {
            for (_, cont) in lines.by_ref() {
                let cont = strip_comment(cont).trim();
                val.push(' ');
                val.push_str(cont);
                if cont.ends_with(']') {
                    break;
                }
            }
            if !val.ends_with(']') {
                return Err(format!("{origin}:{ln}: unterminated array"));
            }
        }
        let value = if val.starts_with('[') {
            let inner = &val[1..val.len() - 1];
            let mut items = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                items.push(unquote(part, origin, ln)?);
            }
            Value::List(items)
        } else if val.starts_with('"') {
            Value::Str(unquote(&val, origin, ln)?)
        } else {
            Value::Int(
                val.parse()
                    .map_err(|_| format!("{origin}:{ln}: expected an integer, got `{val}`"))?,
            )
        };
        let table = tables
            .last_mut()
            .ok_or_else(|| format!("{origin}:{ln}: key before any [table] header"))?;
        table.entries.push((key, value));
    }
    Ok(tables)
}

/// The PR-6 `[files]` / `"path" = count` shape shared by
/// `unsafe-budget.toml` and `relaxed-allowlist.toml`.
pub fn parse_counts(text: &str, origin: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut map = BTreeMap::new();
    for t in parse(text, origin)? {
        if t.name != "files" {
            continue;
        }
        for (k, v) in t.entries {
            match v {
                Value::Int(n) => {
                    map.insert(k, n);
                }
                _ => return Err(format!("{origin}: [files] entry {k:?} must be an integer")),
            }
        }
    }
    Ok(map)
}

/// A lock class from `lock-order.toml`: the named mutex/rwlock family a
/// guard-acquisition site belongs to, keyed by (file, receiver ident).
#[derive(Debug, Clone)]
pub struct LockClass {
    pub name: String,
    pub file: String,
    pub recv: Vec<String>,
    pub doc: String,
}

/// A declared may-nest edge: holding `from` while acquiring `to` is legal.
#[derive(Debug, Clone)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub why: String,
}

#[derive(Debug, Default)]
pub struct LockOrder {
    pub classes: Vec<LockClass>,
    pub edges: Vec<LockEdge>,
}

pub fn parse_lock_order(text: &str, origin: &str) -> Result<LockOrder, String> {
    let mut out = LockOrder::default();
    for t in parse(text, origin)? {
        match t.name.as_str() {
            "class" => out.classes.push(LockClass {
                name: t.get_str("name", origin)?,
                file: t.get_str("file", origin)?,
                recv: t.get_list("recv", origin)?,
                doc: t.get_str("doc", origin)?,
            }),
            "edge" => out.edges.push(LockEdge {
                from: t.get_str("from", origin)?,
                to: t.get_str("to", origin)?,
                why: t.get_str("why", origin)?,
            }),
            other => return Err(format!("{origin}: unknown table [[{other}]]")),
        }
    }
    let mut seen = std::collections::BTreeSet::new();
    for c in &out.classes {
        if !seen.insert(c.name.clone()) {
            return Err(format!("{origin}: duplicate class {:?}", c.name));
        }
    }
    for e in &out.edges {
        if !seen.contains(&e.from) || !seen.contains(&e.to) {
            return Err(format!(
                "{origin}: edge {} -> {} references an undeclared class",
                e.from, e.to
            ));
        }
    }
    Ok(out)
}

/// One Release/Acquire pairing from `ordering-pairs.toml`. Site keys are
/// `"<file>::<Type::fn>"`; a fn with two sites on the same side lists
/// its key twice.
#[derive(Debug, Clone)]
pub struct OrderingPair {
    pub name: String,
    pub doc: String,
    pub release: Vec<String>,
    pub acquire: Vec<String>,
}

pub fn parse_ordering_pairs(text: &str, origin: &str) -> Result<Vec<OrderingPair>, String> {
    let mut out = Vec::new();
    for t in parse(text, origin)? {
        let Some(name) = t.name.strip_prefix("pair.") else {
            return Err(format!("{origin}: unexpected table [{}] (want [pair.<name>])", t.name));
        };
        let pair = OrderingPair {
            name: name.to_string(),
            doc: t.get_str("doc", origin)?,
            release: t.get_list("release", origin)?,
            acquire: t.get_list("acquire", origin)?,
        };
        if pair.release.is_empty() || pair.acquire.is_empty() {
            return Err(format!(
                "{origin}: [pair.{name}] must list at least one release and one acquire site \
                 (a one-sided pair is an orphan by construction)"
            ));
        }
        out.push(pair);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_shape_still_parses() {
        let text = "# c\n[files]\n\"rust/src/a.rs\" = 3\n\"rust/src/b.rs\" = 0 # note\n";
        let m = parse_counts(text, "t").unwrap();
        assert_eq!(m.get("rust/src/a.rs"), Some(&3));
        assert_eq!(m.get("rust/src/b.rs"), Some(&0));
        assert!(parse_counts("[files]\nbad line\n", "t").is_err());
        assert!(parse_counts("[files]\n\"a\" = x\n", "t").is_err());
    }

    #[test]
    fn lock_order_shape() {
        let text = "\
[[class]]
name = \"a.x\"
file = \"rust/src/a.rs\"
recv = [\"x\", \"x_of\"]
doc = \"d\"

[[class]]
name = \"b.y\"
file = \"rust/src/b.rs\"
recv = [\"y\"]
doc = \"d\"

[[edge]]
from = \"a.x\"
to = \"b.y\"
why = \"a calls into b under its stripe\"
";
        let lo = parse_lock_order(text, "t").unwrap();
        assert_eq!(lo.classes.len(), 2);
        assert_eq!(lo.classes[0].recv, vec!["x", "x_of"]);
        assert_eq!(lo.edges.len(), 1);
        // edges must reference declared classes
        let bad = "[[edge]]\nfrom = \"a\"\nto = \"b\"\nwhy = \"w\"\n";
        assert!(parse_lock_order(bad, "t").is_err());
    }

    #[test]
    fn ordering_pairs_shape_and_multiline_arrays() {
        let text = "\
[pair.p]
doc = \"d\"
release = [
    \"rust/src/a.rs::f\",  # trailing comment
    \"rust/src/b.rs::T::g\",
]
acquire = [\"rust/src/c.rs::h\"]
";
        let pairs = parse_ordering_pairs(text, "t").unwrap();
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].release.len(), 2);
        assert_eq!(pairs[0].release[1], "rust/src/b.rs::T::g");
        // one-sided pair is rejected
        let bad = "[pair.p]\ndoc = \"d\"\nrelease = [\"a\"]\nacquire = []\n";
        assert!(parse_ordering_pairs(bad, "t").is_err());
    }
}
