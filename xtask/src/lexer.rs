//! Dependency-free Rust lexer shared by every lint/analyze pass.
//!
//! The PR-6 lint scanned comment-stripped *lines*, which cannot see lock
//! scopes, function boundaries, or multi-line block comments. This module
//! tokenizes a whole file instead — comment-, string-, raw-string- and
//! char-literal-aware — then annotates brace depth and extracts `impl`
//! blocks and `fn` bodies so passes can reason about nesting and
//! attribute findings to an enclosing function.
//!
//! Scope policy: items behind a plain `#[cfg(test)]` attribute are
//! dropped from the token stream (the whole item, not just the line), so
//! passes never fire inside test modules regardless of where they sit in
//! the file. Raw lines are kept alongside for `lint:allow(...)` /
//! `SAFETY:` lookback, which is deliberately comment-based.

/// How far above a flagged line a `lint:allow` comment may sit.
pub const ALLOW_LOOKBACK: usize = 6;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Id,
    Num,
    Str,
    CharLit,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    /// 1-based source line.
    pub line: usize,
    pub kind: Kind,
    /// Brace depth; a `}` carries the depth of the block it closes.
    pub depth: u32,
}

impl Tok {
    fn new(text: impl Into<String>, line: usize, kind: Kind) -> Self {
        Tok { text: text.into(), line, kind, depth: 0 }
    }

    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_id(&self, text: &str) -> bool {
        self.kind == Kind::Id && self.text == text
    }
}

pub const KEYWORDS: &[&str] = &[
    "fn", "let", "if", "else", "match", "while", "for", "loop", "return", "impl", "struct",
    "enum", "trait", "mod", "use", "pub", "const", "static", "type", "where", "unsafe", "move",
    "ref", "mut", "dyn", "as", "in", "break", "continue", "self", "Self", "super", "crate",
    "true", "false",
];

pub fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize Rust source. Comments vanish; string/char bodies survive as
/// single opaque tokens so their contents can never look like code.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        // block comment (nested, may span lines)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte-raw strings: r"..", r#".."#, br#".."#
        if c == b'r' || c == b'b' {
            let mut j = i;
            if b[j] == b'b' && j + 1 < n && b[j + 1] == b'r' {
                j += 1;
            }
            if b[j] == b'r' {
                let mut k = j + 1;
                while k < n && b[k] == b'#' {
                    k += 1;
                }
                if k < n && b[k] == b'"' {
                    let hashes = k - (j + 1);
                    let mut close = String::from("\"");
                    close.push_str(&"#".repeat(hashes));
                    let start = k + 1;
                    let end = src[start..].find(&close).map(|p| start + p + close.len());
                    let end = end.unwrap_or(n);
                    line += src[i..end].matches('\n').count();
                    toks.push(Tok::new(&src[i..end], line, Kind::Str));
                    i = end;
                    continue;
                }
            }
            // plain byte string b"..."
            if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
                let (end, nl) = scan_quoted(src, i + 1);
                line += nl;
                toks.push(Tok::new(&src[i..end], line, Kind::Str));
                i = end;
                continue;
            }
            // else: falls through to identifier handling below
        }
        if c == b'"' {
            let (end, nl) = scan_quoted(src, i);
            line += nl;
            toks.push(Tok::new(&src[i..end], line, Kind::Str));
            i = end;
            continue;
        }
        if c == b'\'' {
            // lifetime ('a) or char literal ('a', '\n', '{')
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == b'\'' && j == i + 2 {
                    toks.push(Tok::new(&src[i..j + 1], line, Kind::CharLit));
                    i = j + 1;
                } else {
                    toks.push(Tok::new(&src[i..j], line, Kind::Lifetime));
                    i = j;
                }
                continue;
            }
            let mut j = i + 1;
            while j < n && b[j] != b'\'' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            let end = (j + 1).min(n);
            toks.push(Tok::new(&src[i..end], line, Kind::CharLit));
            i = end;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok::new(&src[i..j], line, Kind::Id));
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            let mut seen_dot = false;
            while j < n {
                if is_ident_cont(b[j]) {
                    j += 1;
                } else if b[j] == b'.'
                    && !seen_dot
                    && j + 1 < n
                    && b[j + 1].is_ascii_digit()
                {
                    seen_dot = true;
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok::new(&src[i..j], line, Kind::Num));
            i = j;
            continue;
        }
        toks.push(Tok::new(&src[i..i + 1], line, Kind::Punct));
        i += 1;
    }
    toks
}

/// Scan a `"..."` literal starting at the opening quote; returns
/// (index past the closing quote, newlines inside).
fn scan_quoted(src: &str, open: usize) -> (usize, usize) {
    let b = src.as_bytes();
    let n = b.len();
    let mut j = open + 1;
    let mut nl = 0;
    while j < n && b[j] != b'"' {
        if b[j] == b'\\' {
            j += 1;
        }
        if j < n && b[j] == b'\n' {
            nl += 1;
        }
        j += 1;
    }
    ((j + 1).min(n), nl)
}

/// Drop tokens of items gated behind a plain `#[cfg(test)]` attribute
/// (the attribute, any stacked attributes after it, and the item body).
pub fn strip_test_items(toks: Vec<Tok>) -> Vec<Tok> {
    let mut out = Vec::with_capacity(toks.len());
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].is("#") && i + 1 < n && toks[i + 1].is("[") {
            // collect the attribute's inner tokens
            let mut j = i + 2;
            let mut depth = 1;
            let mut inner: Vec<&str> = Vec::new();
            while j < n && depth > 0 {
                if toks[j].is("[") {
                    depth += 1;
                } else if toks[j].is("]") {
                    depth -= 1;
                }
                if depth > 0 {
                    inner.push(&toks[j].text);
                }
                j += 1;
            }
            let is_cfg_test = inner.len() >= 4
                && inner[0] == "cfg"
                && inner[1] == "("
                && inner[2] == "test"
                && inner[3] == ")";
            if !is_cfg_test {
                out.extend_from_slice(&toks[i..j]);
                i = j;
                continue;
            }
            // skip stacked attributes after #[cfg(test)]
            i = j;
            while i < n && toks[i].is("#") && i + 1 < n && toks[i + 1].is("[") {
                let mut d = 1;
                i += 2;
                while i < n && d > 0 {
                    if toks[i].is("[") {
                        d += 1;
                    } else if toks[i].is("]") {
                        d -= 1;
                    }
                    i += 1;
                }
            }
            // skip the item: to the matching `}` of its first top-level
            // `{`, or a `;` before any brace opens
            let mut d = 0i32;
            while i < n {
                if toks[i].is("{") {
                    d += 1;
                } else if toks[i].is("}") {
                    d -= 1;
                    if d == 0 {
                        i += 1;
                        break;
                    }
                } else if toks[i].is(";") && d == 0 {
                    i += 1;
                    break;
                }
                i += 1;
            }
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// Annotate brace depth in place; a `}` carries the depth of the block
/// it closes (so "kill guards acquired at depth >= this token's depth"
/// is a single comparison).
pub fn annotate_depth(toks: &mut [Tok]) {
    let mut d: u32 = 0;
    for t in toks.iter_mut() {
        if t.text == "{" {
            d += 1;
            t.depth = d;
        } else if t.text == "}" {
            t.depth = d;
            d = d.saturating_sub(1);
        } else {
            t.depth = d;
        }
    }
}

/// A function definition found in the token stream.
#[derive(Debug, Clone)]
pub struct FnDef {
    pub name: String,
    /// `impl` type the fn lives in, if any.
    pub self_type: Option<String>,
    /// token index of the `fn` keyword
    pub start: usize,
    /// token index of the body's `{`
    pub body_start: usize,
    /// token index just past the body's `}`
    pub end: usize,
    pub line: usize,
}

impl FnDef {
    /// `Type::name` for methods, bare `name` for free functions.
    pub fn key(&self) -> String {
        match &self.self_type {
            Some(t) => format!("{t}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// `impl` block brace ranges: (self type, `{` index, index past `}`).
fn find_impls(toks: &[Tok]) -> Vec<(String, usize, usize)> {
    let mut impls = Vec::new();
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].is_id("impl") {
            let mut j = i + 1;
            // skip generic params
            if j < n && toks[j].is("<") {
                let mut d = 1;
                j += 1;
                while j < n && d > 0 {
                    if toks[j].is("<") {
                        d += 1;
                    } else if toks[j].is(">") {
                        d -= 1;
                    }
                    j += 1;
                }
            }
            let mut after_for = None;
            let mut body = None;
            let mut k = j;
            while k < n {
                if toks[k].is("{") {
                    body = Some(k);
                    break;
                }
                if toks[k].is(";") {
                    break;
                }
                if toks[k].is_id("for") {
                    after_for = Some(k);
                }
                k += 1;
            }
            let Some(body) = body else {
                i += 1;
                continue;
            };
            // self type: first non-keyword ident after `for` (trait impls)
            // or after `impl` (inherent impls), skipping generic args
            let mut p = after_for.map(|f| f + 1).unwrap_or(j);
            let mut ty = None;
            while p < body {
                let t = &toks[p];
                if t.kind == Kind::Id && !is_keyword(&t.text) {
                    ty = Some(t.text.clone());
                    break;
                }
                if t.is("<") {
                    let mut d = 1;
                    p += 1;
                    while p < body && d > 0 {
                        if toks[p].is("<") {
                            d += 1;
                        } else if toks[p].is(">") {
                            d -= 1;
                        }
                        p += 1;
                    }
                    continue;
                }
                p += 1;
            }
            let mut d = 1;
            let mut e = body + 1;
            while e < n && d > 0 {
                if toks[e].is("{") {
                    d += 1;
                } else if toks[e].is("}") {
                    d -= 1;
                }
                e += 1;
            }
            if let Some(ty) = ty {
                impls.push((ty, body, e));
            }
            i = body + 1; // descend: nested fns are found by find_fns
            continue;
        }
        i += 1;
    }
    impls
}

fn find_fns(toks: &[Tok], impls: &[(String, usize, usize)]) -> Vec<FnDef> {
    let mut fns = Vec::new();
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].is_id("fn") && i + 1 < n && toks[i + 1].kind == Kind::Id {
            let name = toks[i + 1].text.clone();
            let mut k = i + 2;
            let mut paren = 0i32;
            let mut body = None;
            while k < n {
                if toks[k].is("(") {
                    paren += 1;
                } else if toks[k].is(")") {
                    paren -= 1;
                } else if toks[k].is("{") && paren == 0 {
                    body = Some(k);
                    break;
                } else if toks[k].is(";") && paren == 0 {
                    break; // trait method signature, no body
                }
                k += 1;
            }
            if let Some(body) = body {
                let mut d = 1;
                let mut e = body + 1;
                while e < n && d > 0 {
                    if toks[e].is("{") {
                        d += 1;
                    } else if toks[e].is("}") {
                        d -= 1;
                    }
                    e += 1;
                }
                // innermost enclosing impl wins
                let mut self_type = None;
                for (ty, s, t_end) in impls {
                    if *s < i && i < *t_end {
                        self_type = Some(ty.clone());
                    }
                }
                fns.push(FnDef {
                    name,
                    self_type,
                    start: i,
                    body_start: body,
                    end: e,
                    line: toks[i].line,
                });
                i = body + 1;
                continue;
            }
        }
        i += 1;
    }
    fns
}

/// One lexed source file: non-test token stream with depth annotations,
/// fn table, and the raw lines (for allow/SAFETY lookback).
pub struct FileLex {
    /// repo-relative path with forward slashes
    pub rel: String,
    pub raw: Vec<String>,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnDef>,
}

impl FileLex {
    pub fn from_source(rel: &str, text: &str) -> FileLex {
        let mut toks = strip_test_items(lex(text));
        annotate_depth(&mut toks);
        let impls = find_impls(&toks);
        let fns = find_fns(&toks, &impls);
        FileLex {
            rel: rel.to_string(),
            raw: text.lines().map(str::to_string).collect(),
            toks,
            fns,
        }
    }

    /// Innermost fn whose range contains token index `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<&FnDef> {
        self.fns
            .iter()
            .filter(|f| f.start <= i && i < f.end)
            .max_by_key(|f| f.start)
    }

    /// `lint:allow(<rule>)` on the given 1-based line or up to
    /// ALLOW_LOOKBACK lines above it.
    pub fn has_allow(&self, line: usize, rule: &str) -> bool {
        let marker = format!("lint:allow({rule})");
        let hi = line.min(self.raw.len());
        let lo = hi.saturating_sub(ALLOW_LOOKBACK + 1);
        self.raw[lo..hi].iter().any(|l| l.contains(&marker))
    }

    /// Site key `"<rel>::<Type::fn>"` for the fn enclosing token `i`.
    pub fn site_key(&self, i: usize) -> Option<String> {
        self.enclosing_fn(i).map(|f| format!("{}::{}", self.rel, f.key()))
    }
}

/// Lex every `rust/src/**.rs` under `root`, sorted by path.
pub fn collect_sources(root: &std::path::Path) -> std::io::Result<Vec<FileLex>> {
    let src = root.join("rust/src");
    let mut files = Vec::new();
    let mut stack = vec![src.clone()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = format!(
                    "rust/src/{}",
                    path.strip_prefix(&src).expect("path under rust/src").display()
                )
                .replace('\\', "/");
                let text = std::fs::read_to_string(&path)?;
                files.push(FileLex::from_source(&rel, &text));
            }
        }
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(files)
}

/// Receiver identifier of the method call whose `.` is at `dot`:
/// `x.y.lock()` -> `y`; `self.stripe_of(i).lock()` -> `stripe_of`.
pub fn recv_ident(toks: &[Tok], dot: usize) -> Option<&str> {
    if dot == 0 {
        return None;
    }
    let mut i = dot - 1;
    if toks[i].is(")") {
        let mut d = 1;
        while i > 0 && d > 0 {
            i -= 1;
            if toks[i].is(")") {
                d += 1;
            } else if toks[i].is("(") {
                d -= 1;
            }
        }
        if i == 0 {
            return None;
        }
        i -= 1;
    }
    (toks[i].kind == Kind::Id).then(|| toks[i].text.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_comment_spanning_lines_is_not_code() {
        // the PR-6 code_part() bug: interior of a multi-line /* */ was
        // scanned as code
        let toks = lex("let a = 1;\n/* unsafe\n .unwrap()\n*/\nlet b = 2;");
        assert!(!toks.iter().any(|t| t.text.contains("unsafe")));
        assert!(!toks.iter().any(|t| t.text == "unwrap"));
        assert!(toks.iter().any(|t| t.is_id("b") && t.line == 5));
    }

    #[test]
    fn raw_string_with_slashes_does_not_truncate() {
        // the other code_part() bug: `//` inside a raw string truncated
        // the rest of the line, hiding real code after it
        let toks = lex(r##"let u = r#"https://a"#; x.unwrap();"##);
        assert!(toks.iter().any(|t| t.is_id("unwrap")));
        // and the url itself is an opaque Str token, not code
        assert!(toks.iter().any(|t| t.kind == Kind::Str && t.text.contains("https")));
    }

    #[test]
    fn strings_chars_lifetimes_are_opaque() {
        let toks = lex("let s = \"unsafe // x\"; let c = '\\n'; fn f<'a>(x: &'a u8) {}");
        assert!(!toks.iter().any(|t| t.kind == Kind::Id && t.text == "unsafe"));
        assert!(toks.iter().any(|t| t.kind == Kind::Lifetime && t.text == "'a"));
        let toks = lex("let q = '\"'; let x = 1; // not in a string");
        assert!(toks.iter().any(|t| t.is_id("x")));
        assert!(!toks.iter().any(|t| t.text.contains("not in")));
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* b */ still comment */ let x = 1;");
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Id).count(), 2); // let, x
    }

    #[test]
    fn cfg_test_items_are_stripped() {
        let src = "fn live() { a(); }\n#[cfg(test)]\n\
                   mod tests { fn t() { banned(); } }\nfn tail() {}";
        let f = FileLex::from_source("rust/src/x.rs", src);
        assert!(!f.toks.iter().any(|t| t.is_id("banned")));
        assert!(f.toks.iter().any(|t| t.is_id("tail")));
        assert_eq!(f.fns.len(), 2);
        // non-test cfgs are kept
        let src = "#[cfg(loom)]\nfn shim() { kept(); }";
        let f = FileLex::from_source("rust/src/x.rs", src);
        assert!(f.toks.iter().any(|t| t.is_id("kept")));
    }

    #[test]
    fn fn_table_attributes_methods_to_impl_type() {
        let src = "impl Foo { fn m(&self) { x(); } }\nfn free() {}\n\
                   impl Bar for Foo { fn n(&self) {} }";
        let f = FileLex::from_source("rust/src/x.rs", src);
        let keys: Vec<String> = f.fns.iter().map(|d| d.key()).collect();
        assert_eq!(keys, vec!["Foo::m", "free", "Foo::n"]);
        let xi = f.toks.iter().position(|t| t.is_id("x")).unwrap();
        assert_eq!(f.enclosing_fn(xi).unwrap().key(), "Foo::m");
    }

    #[test]
    fn depth_and_recv_ident() {
        let mut toks = lex("fn f() { { g(); } }");
        annotate_depth(&mut toks);
        let gi = toks.iter().position(|t| t.is_id("g")).unwrap();
        assert_eq!(toks[gi].depth, 2);
        let toks = lex("self.stripe_of(i).lock()");
        let dot = toks.iter().rposition(|t| t.is(".")).unwrap();
        assert_eq!(recv_ident(&toks, dot), Some("stripe_of"));
        let toks = lex("self.state.lock()");
        let dot = toks.iter().rposition(|t| t.is(".")).unwrap();
        assert_eq!(recv_ident(&toks, dot), Some("state"));
    }

    #[test]
    fn allow_lookback_window() {
        let src = "a\nb\n// lint:allow(some-rule) — why\nc\nd\n";
        let f = FileLex::from_source("rust/src/x.rs", src);
        assert!(f.has_allow(3, "some-rule"));
        assert!(f.has_allow(4, "some-rule"));
        assert!(!f.has_allow(2, "some-rule"));
        assert!(!f.has_allow(4, "other-rule"));
    }
}
