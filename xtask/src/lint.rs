//! The four PR-6 lint rules, ported from comment-stripped lines onto the
//! shared token stream (`lexer`). Semantics are unchanged — same rule
//! names, same allow/budget/ratchet behavior, same messages — but the
//! scanner now understands multi-line block comments, raw strings, and
//! `#[cfg(test)]` items anywhere in a file, which the old `code_part()`
//! line stripper did not.

use crate::config::parse_counts;
use crate::lexer::{collect_sources, FileLex, Kind, Tok};
use std::collections::BTreeMap;
use std::path::Path;

pub const NARROWING: &str = "narrowing-cast";
pub const UNSAFE: &str = "unsafe-budget";
pub const UNWRAP: &str = "unwrap-ban";
pub const RELAXED: &str = "relaxed-ordering";

/// How far above an `unsafe` a SAFETY contract may sit.
const SAFETY_LOOKBACK: usize = 10;

fn violation(file: &FileLex, line: usize, rule: &str, msg: &str) -> String {
    let text = file.raw.get(line - 1).map(|s| s.trim()).unwrap_or("");
    format!("{}:{line}: [{rule}] {msg}: {text}", file.rel)
}

/// Token indices grouped by source line, in order.
fn lines_of(toks: &[Tok]) -> Vec<(usize, Vec<usize>)> {
    let mut out: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        match out.last_mut() {
            Some((ln, v)) if *ln == t.line => v.push(i),
            _ => out.push((t.line, vec![i])),
        }
    }
    out
}

// ---------------------------------------------------------------- rules

/// Byte-math markers on a line of tokens. `offsets[` is excluded: CSR
/// offset *arrays* index by id, which is not byte math.
fn is_byte_math(toks: &[Tok], idxs: &[usize]) -> bool {
    for (k, &i) in idxs.iter().enumerate() {
        let t = &toks[i];
        if t.kind == Kind::Id && t.text.contains("byte") {
            return true;
        }
        if t.kind == Kind::Id && t.text.contains("offset") {
            let next_is_bracket = idxs
                .get(k + 1)
                .is_some_and(|&j| toks[j].is("["));
            if !(t.text == "offsets" && next_is_bracket) {
                return true;
            }
        }
        if t.is("*") {
            if let Some(&j) = idxs.get(k + 1) {
                if toks[j].kind == Kind::Num && toks[j].text == "4" {
                    return true;
                }
            }
        }
    }
    false
}

pub fn check_narrowing(file: &FileLex, out: &mut Vec<String>) {
    if file.rel.ends_with("util/bytes.rs") {
        return; // the sanctioned home of byte reinterpretation
    }
    let toks = &file.toks;
    for (line, idxs) in lines_of(toks) {
        let has_cast = idxs.iter().enumerate().any(|(k, &i)| {
            toks[i].is_id("as")
                && idxs
                    .get(k + 1)
                    .is_some_and(|&j| toks[j].is_id("usize") || toks[j].is_id("u32"))
        });
        if has_cast && is_byte_math(toks, &idxs) && !file.has_allow(line, NARROWING) {
            out.push(violation(
                file,
                line,
                NARROWING,
                "narrowing cast in offset/byte math (widen first: `i as u64 * dim as u64 * 4`)",
            ));
        }
    }
}

fn has_safety_contract(file: &FileLex, line: usize) -> bool {
    let hi = line.min(file.raw.len());
    let lo = hi.saturating_sub(SAFETY_LOOKBACK + 1);
    file.raw[lo..hi].iter().any(|l| l.contains("SAFETY") || l.contains("# Safety"))
}

pub fn check_unsafe(
    file: &FileLex,
    budget: &BTreeMap<String, usize>,
    out: &mut Vec<String>,
) -> usize {
    let mut count = 0;
    for (line, idxs) in lines_of(&file.toks) {
        let n = idxs.iter().filter(|&&i| file.toks[i].is_id("unsafe")).count();
        if n == 0 {
            continue;
        }
        count += n;
        if !has_safety_contract(file, line) && !file.has_allow(line, UNSAFE) {
            out.push(violation(
                file,
                line,
                UNSAFE,
                "unsafe without a SAFETY: contract in the 10 lines above",
            ));
        }
    }
    match (count, budget.get(&file.rel)) {
        (0, None) => {}
        (n, Some(&b)) if n == b => {}
        (n, Some(&b)) if n > b => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s), budget is {b} — do not add unsafe; \
             refactor or (exceptionally) raise the budget with review",
            file.rel
        )),
        (n, Some(&b)) => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s), budget is {b} — \
             lower the budget in unsafe-budget.toml (the count may only go down)",
            file.rel
        )),
        (n, None) => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s) but the file is not in unsafe-budget.toml",
            file.rel
        )),
    }
    count
}

fn unwrap_ban_applies(rel: &str) -> bool {
    rel.starts_with("rust/src/kvstore/")
        || rel.starts_with("rust/src/serve/")
        || rel == "rust/src/train/prefetch.rs"
}

pub fn check_unwrap(file: &FileLex, out: &mut Vec<String>) {
    if !unwrap_ban_applies(&file.rel) {
        return;
    }
    let toks = &file.toks;
    for i in 0..toks.len() {
        if !toks[i].is(".") || i + 2 >= toks.len() || !toks[i + 2].is("(") {
            continue;
        }
        let hit = (toks[i + 1].is_id("unwrap")
            && toks.get(i + 3).is_some_and(|t| t.is(")")))
            || toks[i + 1].is_id("expect");
        if hit && !file.has_allow(toks[i].line, UNWRAP) {
            out.push(violation(
                file,
                toks[i].line,
                UNWRAP,
                "unwrap/expect in I/O-facing code (return a Result or recover from poison)",
            ));
        }
    }
}

/// `<ident ending in Ordering>::Relaxed` — the suffix match keeps the
/// loom shim's `StdOrdering::Relaxed` sites counted, as the old
/// substring scan did.
fn is_relaxed_site(toks: &[Tok], i: usize) -> bool {
    toks[i].is_id("Relaxed")
        && i >= 3
        && toks[i - 1].is(":")
        && toks[i - 2].is(":")
        && toks[i - 3].kind == Kind::Id
        && toks[i - 3].text.ends_with("Ordering")
}

pub fn check_relaxed(
    file: &FileLex,
    allow: &BTreeMap<String, usize>,
    out: &mut Vec<String>,
) -> usize {
    let toks = &file.toks;
    let mut count = 0;
    let mut first = None;
    for i in 0..toks.len() {
        if is_relaxed_site(toks, i) && !file.has_allow(toks[i].line, RELAXED) {
            count += 1;
            first.get_or_insert(toks[i].line);
        }
    }
    if count == 0 {
        return 0;
    }
    match allow.get(&file.rel) {
        Some(&max) if count <= max => {}
        Some(&max) => out.push(format!(
            "{}: [{RELAXED}] {count} Ordering::Relaxed site(s), allowlist permits {max} — \
             new Relaxed uses need a docs/CONCURRENCY.md audit entry first",
            file.rel
        )),
        None => out.push(violation(
            file,
            first.unwrap_or(1),
            RELAXED,
            "Ordering::Relaxed in a file absent from relaxed-allowlist.toml \
             (audit it in docs/CONCURRENCY.md, then allowlist it)",
        )),
    }
    count
}

// ---------------------------------------------------------------- driver

pub fn run_lint(root: &Path) -> Result<Vec<String>, String> {
    let budget_path = root.join("unsafe-budget.toml");
    let allow_path = root.join("relaxed-allowlist.toml");
    let budget = parse_counts(
        &std::fs::read_to_string(&budget_path)
            .map_err(|e| format!("{}: {e}", budget_path.display()))?,
        "unsafe-budget.toml",
    )?;
    let allow = parse_counts(
        &std::fs::read_to_string(&allow_path)
            .map_err(|e| format!("{}: {e}", allow_path.display()))?,
        "relaxed-allowlist.toml",
    )?;
    let files = collect_sources(root).map_err(|e| format!("scanning rust/src: {e}"))?;
    Ok(lint_files(&files, &budget, &allow))
}

pub fn lint_files(
    files: &[FileLex],
    budget: &BTreeMap<String, usize>,
    allow: &BTreeMap<String, usize>,
) -> Vec<String> {
    let mut out = Vec::new();
    let mut seen_unsafe: BTreeMap<String, usize> = BTreeMap::new();
    let mut seen_relaxed: BTreeMap<String, usize> = BTreeMap::new();
    for file in files {
        check_narrowing(file, &mut out);
        check_unwrap(file, &mut out);
        let u = check_unsafe(file, budget, &mut out);
        if u > 0 {
            seen_unsafe.insert(file.rel.clone(), u);
        }
        let r = check_relaxed(file, allow, &mut out);
        if r > 0 {
            seen_relaxed.insert(file.rel.clone(), r);
        }
    }
    // stale config entries hide future regressions: flag them
    for path in budget.keys() {
        if !seen_unsafe.contains_key(path) {
            out.push(format!(
                "unsafe-budget.toml: [{UNSAFE}] stale entry {path:?} (file gone or unsafe-free) \
                 — remove it"
            ));
        }
    }
    for path in allow.keys() {
        if !seen_relaxed.contains_key(path) {
            out.push(format!(
                "relaxed-allowlist.toml: [{RELAXED}] stale entry {path:?} (file gone or \
                 Relaxed-free) — remove it"
            ));
        }
    }
    out
}

// ------------------------------------------------------------ self-test

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(rel: &str, body: &str) -> FileLex {
        FileLex::from_source(rel, body)
    }

    #[test]
    fn narrowing_flags_seeded_violation() {
        let f = fixture("rust/src/store/x.rs", "fn f() { let off = (i * dim * 4) as usize; }\n");
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("narrowing-cast"));
    }

    #[test]
    fn narrowing_respects_allow_and_scope() {
        // annotated site passes
        let f = fixture(
            "rust/src/store/x.rs",
            "// lint:allow(narrowing-cast) — bounded by the clamp below\n\
             fn f() { let off = (i * dim * 4) as usize; }\n",
        );
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // util/bytes.rs is exempt wholesale
        let f = fixture("rust/src/util/bytes.rs", "fn f() { let off = (i * dim * 4) as usize; }\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // id-space casts (no byte-math marker) pass
        let f = fixture(
            "rust/src/kg/x.rs",
            "fn f() { let id = v as usize; let n = k.len() as u32; }\n",
        );
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // CSR offset arrays are id indexing, not byte math
        let f =
            fixture("rust/src/kg/x.rs", "fn f() { let lo = self.offsets[v as usize] as usize; }\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn narrowing_ignores_test_modules_and_comments() {
        let f = fixture(
            "rust/src/store/x.rs",
            "fn ok() {}\n#[cfg(test)]\nmod tests { fn t() { let off = (i * 4) as usize; } }\n",
        );
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        let f = fixture("rust/src/store/x.rs", "// old code: let off = (i * 4) as usize;\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn narrowing_sees_through_block_comments_and_raw_strings() {
        // regression for the code_part() bugs this module replaced:
        // (1) a multi-line block comment's interior is not code
        let f = fixture(
            "rust/src/store/x.rs",
            "fn f() {}\n/* disabled:\nlet off = (i * dim * 4) as usize;\n*/\n",
        );
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // (2) a raw string containing `//` no longer truncates the line:
        // real code after it is still scanned
        let f = fixture(
            "rust/src/store/x.rs",
            "fn f() { let u = r#\"https://x\"#; let off = (i * dim * 4) as usize; }\n",
        );
        check_narrowing(&f, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
    }

    #[test]
    fn unsafe_requires_safety_contract_and_budget() {
        let mut budget = BTreeMap::new();
        budget.insert("rust/src/store/x.rs".to_string(), 1);
        // contract present, budget exact: clean
        let f = fixture(
            "rust/src/store/x.rs",
            "// SAFETY: the slice outlives the call\nfn f() { let s = unsafe { mk() }; }\n",
        );
        let mut out = Vec::new();
        assert_eq!(check_unsafe(&f, &budget, &mut out), 1);
        assert!(out.is_empty(), "{out:?}");
        // no contract: violation
        let f = fixture("rust/src/store/x.rs", "fn f() { let s = unsafe { mk() }; }\n");
        check_unsafe(&f, &budget, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("SAFETY"));
    }

    #[test]
    fn unsafe_budget_is_a_ratchet() {
        let mut out = Vec::new();
        let mut budget = BTreeMap::new();
        budget.insert("rust/src/store/x.rs".to_string(), 2);
        let over = "// SAFETY: a\nfn a2() { unsafe { a() }; }\n// SAFETY: b\nfn b2() { unsafe { b() }; }\n\
                    // SAFETY: c\nfn c2() { unsafe { c() }; }\n";
        check_unsafe(&fixture("rust/src/store/x.rs", over), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("budget is 2")), "{out:?}");
        out.clear();
        // under budget is ALSO an error: the count may only go down
        let under = "// SAFETY: a\nfn a2() { unsafe { a() }; }\n";
        check_unsafe(&fixture("rust/src/store/x.rs", under), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("lower the budget")), "{out:?}");
        out.clear();
        // unsafe in a file the budget has never heard of
        check_unsafe(&fixture("rust/src/store/y.rs", under), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("not in unsafe-budget.toml")), "{out:?}");
    }

    #[test]
    fn unsafe_in_kernels_is_budgeted_like_everywhere_else() {
        // The fused kernels (rust/src/models/kernels.rs) are written in
        // autovectorization-friendly safe Rust on purpose — the file has
        // no unsafe-budget.toml entry, so this pins that sneaking a
        // `unsafe` intrinsic block into them fails the lint until the
        // budget is consciously amended (docs/KERNELS.md).
        let budget_path =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("unsafe-budget.toml");
        let budget = parse_counts(
            &std::fs::read_to_string(budget_path).expect("unsafe-budget.toml readable"),
            "unsafe-budget.toml",
        )
        .expect("unsafe-budget.toml parses");
        assert!(
            !budget.contains_key("rust/src/models/kernels.rs"),
            "kernels.rs grew an unsafe budget entry — update this test \
             and docs/KERNELS.md if that was deliberate"
        );
        let mut out = Vec::new();
        let f = fixture(
            "rust/src/models/kernels.rs",
            "// SAFETY: lanes are in bounds\nfn f() { let v = unsafe { load(ptr) }; }\n",
        );
        check_unsafe(&f, &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("not in unsafe-budget.toml")), "{out:?}");
    }

    #[test]
    fn unsafe_token_matching_is_word_bounded() {
        // `unsafety` / `not_unsafe` are single identifier tokens, never
        // counted; string contents are opaque
        let mut budget = BTreeMap::new();
        budget.insert("rust/src/store/x.rs".to_string(), 2);
        let f = fixture(
            "rust/src/store/x.rs",
            "// SAFETY: both\nunsafe fn f() { unsafe { g() } }\n\
             fn h() { let unsafety = 1; not_unsafe(); let s = \"unsafe\"; }\n",
        );
        let mut out = Vec::new();
        assert_eq!(check_unsafe(&f, &budget, &mut out), 2);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unwrap_ban_scoped_to_kvstore_serve_and_prefetch() {
        let mut out = Vec::new();
        let body = "fn f() { let v = rx.recv().unwrap(); let w = tx.send(x).expect(\"send\"); }\n";
        check_unwrap(&fixture("rust/src/kvstore/comm.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        check_unwrap(&fixture("rust/src/train/prefetch.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        // the serving request loop is I/O-facing helper-thread code too
        check_unwrap(&fixture("rust/src/serve/server.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        // other modules are out of scope
        check_unwrap(&fixture("rust/src/store/cache.rs", body), &mut out);
        assert!(out.is_empty(), "{out:?}");
        // annotated designed-panic passes
        let annotated = "// lint:allow(unwrap-ban) — startup path, infallible by construction\n\
                         fn f() { let v = init().expect(\"cannot fail\"); }\n";
        check_unwrap(&fixture("rust/src/kvstore/server.rs", annotated), &mut out);
        assert!(out.is_empty(), "{out:?}");
        // a `.unwrap()` inside a comment or string is not code
        let masked = "fn f() { /* x.unwrap() */ let s = \".unwrap()\"; }\n";
        check_unwrap(&fixture("rust/src/kvstore/server.rs", masked), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn relaxed_requires_allowlist_and_count() {
        let mut allow = BTreeMap::new();
        allow.insert("rust/src/store/cache.rs".to_string(), 2);
        let mut out = Vec::new();
        let two = "fn f() { hits.fetch_add(1, Ordering::Relaxed); \
                   misses.load(Ordering::Relaxed); }\n";
        assert_eq!(check_relaxed(&fixture("rust/src/store/cache.rs", two), &allow, &mut out), 2);
        assert!(out.is_empty(), "{out:?}");
        // one more than the allowlist records
        let three = format!("{two}fn g() {{ evictions.load(Ordering::Relaxed); }}\n");
        check_relaxed(&fixture("rust/src/store/cache.rs", &three), &allow, &mut out);
        assert!(out.iter().any(|v| v.contains("allowlist permits 2")), "{out:?}");
        out.clear();
        // un-allowlisted file
        check_relaxed(&fixture("rust/src/train/new.rs", two), &allow, &mut out);
        assert!(out.iter().any(|v| v.contains("absent from relaxed-allowlist")), "{out:?}");
    }

    #[test]
    fn relaxed_counts_reexported_ordering_aliases() {
        // the loom shim writes `StdOrdering::Relaxed`; the old substring
        // scan counted it and the allowlist budget includes it — the
        // token scan must agree
        let mut allow = BTreeMap::new();
        allow.insert("rust/src/util/sync.rs".to_string(), 1);
        let mut out = Vec::new();
        let f = fixture("rust/src/util/sync.rs", "fn f() { SEED.load(StdOrdering::Relaxed); }\n");
        assert_eq!(check_relaxed(&f, &allow, &mut out), 1);
        assert!(out.is_empty(), "{out:?}");
    }
}
