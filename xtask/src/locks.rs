//! Lock-order / deadlock pass and blocking-under-lock pass.
//!
//! Both walk the same guard-scope simulation:
//!
//! * every empty-args `.lock()` / `.read()` / `.write()` call site is
//!   classified by `(file, receiver ident)` against the `[[class]]`
//!   tables of `lock-order.toml` (an unclassified `.lock()` is an error
//!   — new mutexes must be declared; empty-args `.read()`/`.write()`
//!   with no class are assumed to be `io::Read`/`io::Write` and skipped);
//! * guard lifetime: a `let`-bound guard lives to the end of its block,
//!   a temporary guard (`*x.write().unwrap() = v;`) dies at the `;` of
//!   its statement;
//! * calls are propagated through the crate-local call graph: calling a
//!   fn that (transitively) acquires class C while holding class A is
//!   the edge A -> C. Only fns returning a `*Guard` type leave a guard
//!   live in the caller (`lock_state` / `lock_current` helpers);
//! * every observed edge must be declared as an `[[edge]]` in
//!   `lock-order.toml`; declared-but-unobserved edges are stale; the
//!   declared edge relation must be acyclic (a cycle is a deadlock
//!   recipe even if each edge looks locally reasonable);
//! * while any guard is live, channel `recv`/`recv_timeout`, thread
//!   `join`, CommHandle `drain()`, `wait_timeout`, and file-I/O calls
//!   are flagged (`lint:allow(blocking-under-lock)` with a justification
//!   escapes).
//!
//! `util/sync.rs` is exempt: it *implements* the lock shim the rest of
//! the crate uses, so its `.lock()` sites are the mechanism, not users.

use crate::callgraph::{is_guard_returning, CallGraph, FnRef};
use crate::config::LockOrder;
use crate::lexer::{is_keyword, recv_ident, FileLex, Kind};
use std::collections::{BTreeMap, BTreeSet};

pub const LOCK_ORDER: &str = "lock-order";
pub const BLOCKING: &str = "blocking-under-lock";

const LOCK_EXEMPT: &str = "rust/src/util/sync.rs";

/// Methods that block the calling thread. `recv`/`join`/`drain` only
/// count with empty args: `drain(..)` on a Vec is a range drain, not the
/// CommHandle barrier, and `join("/")` is str::join.
const BLOCKING_METHODS: &[&str] = &["recv", "recv_timeout", "join", "drain", "wait_timeout"];
const BLOCKING_NEED_EMPTY: &[&str] = &["recv", "join", "drain"];
/// File-I/O tokens that reach the kernel.
const BLOCKING_IDENTS: &[&str] = &[
    "read_to_string", "read_exact", "write_all", "sync_all", "create_dir_all", "remove_file",
    "remove_dir_all", "OpenOptions",
];

struct Classifier<'a> {
    /// (file, recv ident) -> class name
    by_site: BTreeMap<(&'a str, &'a str), &'a str>,
}

impl<'a> Classifier<'a> {
    fn new(cfg: &'a LockOrder) -> Self {
        let mut by_site = BTreeMap::new();
        for c in &cfg.classes {
            for r in &c.recv {
                by_site.insert((c.file.as_str(), r.as_str()), c.name.as_str());
            }
        }
        Classifier { by_site }
    }

    fn classify(&self, rel: &str, recv: Option<&str>) -> Option<&'a str> {
        recv.and_then(|r| self.by_site.get(&(rel, r)).copied())
    }
}

/// Direct acquisitions per fn + unclassified-lock diagnostics + which
/// classes were seen at all.
fn direct_acquisitions(
    files: &[FileLex],
    cls: &Classifier,
    out: &mut Vec<String>,
    seen_classes: &mut BTreeSet<String>,
) -> BTreeMap<FnRef, BTreeSet<String>> {
    let mut acq: BTreeMap<FnRef, BTreeSet<String>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, _) in f.fns.iter().enumerate() {
            acq.insert((fi, di), BTreeSet::new());
        }
        if f.rel == LOCK_EXEMPT {
            continue;
        }
        let toks = &f.toks;
        for i in 0..toks.len() {
            if !toks[i].is(".") || i + 3 >= toks.len() || !toks[i + 2].is("(") {
                continue;
            }
            let name = &toks[i + 1].text;
            if toks[i + 1].kind != Kind::Id
                || !(name == "lock" || name == "read" || name == "write")
                || !toks[i + 3].is(")")
            {
                continue;
            }
            let class = cls.classify(&f.rel, recv_ident(toks, i));
            match class {
                Some(c) => {
                    seen_classes.insert(c.to_string());
                    if let Some(fnd) = f.enclosing_fn(i) {
                        let key = (fi, f.fns.iter().position(|x| std::ptr::eq(x, fnd)).unwrap());
                        acq.get_mut(&key).unwrap().insert(c.to_string());
                    }
                }
                None if name == "lock" => {
                    if !f.has_allow(toks[i].line, LOCK_ORDER) {
                        out.push(format!(
                            "{}:{}: [{LOCK_ORDER}] `.lock()` on an unclassified mutex — declare \
                             a [[class]] for it in lock-order.toml (file + receiver ident)",
                            f.rel,
                            toks[i].line
                        ));
                    }
                }
                None => {} // classless .read()/.write(): io traits, not locks
            }
        }
    }
    acq
}

/// Files that declare a `Mutex<`/`RwLock<` must appear in some class —
/// otherwise a brand-new lock never enters the analysis.
fn check_declaration_coverage(files: &[FileLex], cfg: &LockOrder, out: &mut Vec<String>) {
    let class_files: BTreeSet<&str> = cfg.classes.iter().map(|c| c.file.as_str()).collect();
    for f in files {
        if f.rel == LOCK_EXEMPT || class_files.contains(f.rel.as_str()) {
            continue;
        }
        for (i, t) in f.toks.iter().enumerate() {
            let is_lock_ty = (t.is_id("Mutex") || t.is_id("RwLock"))
                && f.toks.get(i + 1).is_some_and(|n| n.is("<"));
            if is_lock_ty && !f.has_allow(t.line, LOCK_ORDER) {
                out.push(format!(
                    "{}:{}: [{LOCK_ORDER}] {} declared in a file with no lock-order.toml class \
                     — add a [[class]] so the deadlock pass can see it",
                    f.rel, t.line, t.text
                ));
                break; // one per file is enough
            }
        }
    }
}

/// DFS cycle check over the declared edge relation.
fn check_cycles(cfg: &LockOrder, out: &mut Vec<String>) {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in &cfg.edges {
        adj.entry(e.from.as_str()).or_default().push(e.to.as_str());
    }
    // 0 = white, 1 = on stack, 2 = done
    let mut color: BTreeMap<&str, u8> = BTreeMap::new();
    fn dfs<'a>(
        n: &'a str,
        adj: &BTreeMap<&'a str, Vec<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(n, 1);
        stack.push(n);
        for &m in adj.get(n).into_iter().flatten() {
            match color.get(m).copied().unwrap_or(0) {
                1 => {
                    let pos = stack.iter().position(|&s| s == m).unwrap_or(0);
                    let mut cyc: Vec<String> = stack[pos..].iter().map(|s| s.to_string()).collect();
                    cyc.push(m.to_string());
                    return Some(cyc);
                }
                0 => {
                    if let Some(c) = dfs(m, adj, color, stack) {
                        return Some(c);
                    }
                }
                _ => {}
            }
        }
        stack.pop();
        color.insert(n, 2);
        None
    }
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for n in nodes {
        if color.get(n).copied().unwrap_or(0) == 0 {
            let mut stack = Vec::new();
            if let Some(cyc) = dfs(n, &adj, &mut color, &mut stack) {
                out.push(format!(
                    "lock-order.toml: [{LOCK_ORDER}] declared edges form a cycle: {} — a \
                     thread following one edge and a thread following another can deadlock; \
                     break the cycle before declaring the new edge",
                    cyc.join(" -> ")
                ));
                return;
            }
        }
    }
}

/// The guard-scope walk shared by lock-order and blocking-under-lock.
#[allow(clippy::too_many_arguments)]
fn simulate(
    files: &[FileLex],
    g: &CallGraph,
    cls: &Classifier,
    trans: &BTreeMap<FnRef, BTreeSet<String>>,
    found_edges: &mut BTreeMap<(String, String), String>,
    out: &mut Vec<String>,
) {
    for (fi, f) in files.iter().enumerate() {
        if f.rel == LOCK_EXEMPT {
            continue;
        }
        let toks = &f.toks;
        for d in &f.fns {
            // (class, depth, let_bound)
            let mut guards: Vec<(String, u32, bool)> = Vec::new();
            let mut let_at: BTreeMap<u32, bool> = BTreeMap::new();
            let mut i = d.body_start + 1;
            while i < d.end.min(toks.len()) {
                let t = &toks[i];
                let dep = t.depth;
                if t.is_id("let") {
                    let_at.insert(dep, true);
                }
                if t.is(";") {
                    guards.retain(|g| g.2 || g.1 != dep);
                    let_at.insert(dep, false);
                }
                if t.is("}") {
                    guards.retain(|g| g.1 < dep);
                    let_at.remove(&dep);
                }
                // what does the expression at `t` acquire / block on?
                let mut acquired: BTreeSet<String> = BTreeSet::new();
                let mut held: BTreeSet<String> = BTreeSet::new();
                let mut blocking: Option<String> = None;
                if t.is(".")
                    && i + 2 < toks.len()
                    && toks[i + 1].kind == Kind::Id
                    && toks[i + 2].is("(")
                {
                    let name = toks[i + 1].text.as_str();
                    let empty = toks.get(i + 3).is_some_and(|x| x.is(")"));
                    if (name == "lock" || name == "read" || name == "write") && empty {
                        if let Some(c) = cls.classify(&f.rel, recv_ident(toks, i)) {
                            acquired.insert(c.to_string());
                            held.insert(c.to_string());
                        }
                    } else if BLOCKING_METHODS.contains(&name)
                        && (!BLOCKING_NEED_EMPTY.contains(&name) || empty)
                    {
                        blocking = Some(format!(".{name}()"));
                    } else {
                        for target in g.resolve(files, fi, toks, i + 1) {
                            if let Some(a) = trans.get(&target) {
                                acquired.extend(a.iter().cloned());
                                let tf = &files[target.0];
                                if is_guard_returning(tf, &tf.fns[target.1]) {
                                    held.extend(a.iter().cloned());
                                }
                            }
                        }
                    }
                } else if t.kind == Kind::Id && BLOCKING_IDENTS.contains(&t.text.as_str()) {
                    blocking = Some(t.text.clone());
                } else if t.kind == Kind::Id
                    && !is_keyword(&t.text)
                    && toks.get(i + 1).is_some_and(|x| x.is("("))
                    && (i == 0 || !toks[i - 1].is("."))
                {
                    for target in g.resolve(files, fi, toks, i) {
                        if let Some(a) = trans.get(&target) {
                            acquired.extend(a.iter().cloned());
                            let tf = &files[target.0];
                            if is_guard_returning(tf, &tf.fns[target.1]) {
                                held.extend(a.iter().cloned());
                            }
                        }
                    }
                }
                if let Some(b) = blocking {
                    if !guards.is_empty() && !f.has_allow(t.line, BLOCKING) {
                        let held_names: Vec<&str> = guards.iter().map(|g| g.0.as_str()).collect();
                        out.push(format!(
                            "{}:{}: [{BLOCKING}] {b} while holding {} (in `{}`) — a blocked \
                             holder stalls every other user of the lock; drop the guard first \
                             or lint:allow(blocking-under-lock) with a reason",
                            f.rel,
                            t.line,
                            held_names.join(" + "),
                            d.key()
                        ));
                    }
                }
                if !acquired.is_empty() {
                    for gshared in &guards {
                        for c in &acquired {
                            found_edges.entry((gshared.0.clone(), c.clone())).or_insert_with(
                                || format!("{}:{} in `{}`", f.rel, t.line, d.key()),
                            );
                        }
                    }
                    let lb = let_at.get(&dep).copied().unwrap_or(false);
                    for c in held {
                        guards.push((c, dep, lb));
                    }
                }
                i += 1;
            }
        }
    }
}

/// Run both passes. `cfg` is the parsed `lock-order.toml`.
pub fn check(files: &[FileLex], g: &CallGraph, cfg: &LockOrder, out: &mut Vec<String>) {
    let cls = Classifier::new(cfg);
    let mut seen_classes = BTreeSet::new();
    let direct = direct_acquisitions(files, &cls, out, &mut seen_classes);
    check_declaration_coverage(files, cfg, out);

    // stale classes: a manifest entry with no live acquisition site
    for c in &cfg.classes {
        if !seen_classes.contains(&c.name) {
            out.push(format!(
                "lock-order.toml: [{LOCK_ORDER}] stale class {:?} — no `.lock()/.read()/.write()` \
                 site matches ({} recv {:?}); remove or update the entry",
                c.name, c.file, c.recv
            ));
        }
    }

    check_cycles(cfg, out);

    let trans = g.propagate(direct);
    let mut found_edges: BTreeMap<(String, String), String> = BTreeMap::new();
    simulate(files, g, &cls, &trans, &mut found_edges, out);

    let declared: BTreeSet<(String, String)> =
        cfg.edges.iter().map(|e| (e.from.clone(), e.to.clone())).collect();
    for ((from, to), site) in &found_edges {
        if !declared.contains(&(from.clone(), to.clone())) {
            out.push(format!(
                "{site}: [{LOCK_ORDER}] acquiring `{to}` while holding `{from}` — this nesting \
                 edge is not declared in lock-order.toml; declare it (with a why) or restructure \
                 so the outer guard is dropped first"
            ));
        }
    }
    for e in &cfg.edges {
        if !found_edges.contains_key(&(e.from.clone(), e.to.clone())) {
            out.push(format!(
                "lock-order.toml: [{LOCK_ORDER}] stale edge {} -> {} — no source site nests \
                 these locks anymore; remove the entry (the manifest must match reality)",
                e.from, e.to
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_lock_order;

    fn run(srcs: &[(&str, &str)], toml: &str) -> Vec<String> {
        let files: Vec<FileLex> =
            srcs.iter().map(|(rel, s)| FileLex::from_source(rel, s)).collect();
        let g = CallGraph::build(&files);
        let cfg = parse_lock_order(toml, "lock-order.toml").expect("fixture toml parses");
        let mut out = Vec::new();
        check(&files, &g, &cfg, &mut out);
        out
    }

    const TWO_CLASSES: &str = "\
[[class]]
name = \"a.x\"
file = \"rust/src/a.rs\"
recv = [\"x\"]
doc = \"d\"
[[class]]
name = \"a.y\"
file = \"rust/src/a.rs\"
recv = [\"y\"]
doc = \"d\"
";

    #[test]
    fn undeclared_nesting_edge_fires() {
        let src = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S { fn f(&self) { let gx = self.x.lock(); let gy = self.y.lock(); } }";
        let out = run(&[("rust/src/a.rs", src)], TWO_CLASSES);
        assert!(
            out.iter().any(|v| v.contains("acquiring `a.y` while holding `a.x`")),
            "{out:?}"
        );
    }

    #[test]
    fn declared_edge_is_clean_and_stale_edge_fires() {
        let src = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S { fn f(&self) { let gx = self.x.lock(); let gy = self.y.lock(); } }";
        let toml = format!(
            "{TWO_CLASSES}[[edge]]\nfrom = \"a.x\"\nto = \"a.y\"\nwhy = \"w\"\n"
        );
        let out = run(&[("rust/src/a.rs", src)], &toml);
        assert!(out.is_empty(), "{out:?}");
        // sequential (non-nested) locking must NOT satisfy the edge
        let seq = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S { fn f(&self) { { let gx = self.x.lock(); } let gy = self.y.lock(); } }";
        let out = run(&[("rust/src/a.rs", seq)], &toml);
        assert!(out.iter().any(|v| v.contains("stale edge a.x -> a.y")), "{out:?}");
    }

    #[test]
    fn declared_cycle_is_a_deadlock() {
        // both orders exist in source AND are declared: the cycle check
        // still fails the build — this is the classic AB/BA deadlock
        let src = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S { fn f(&self) { let gx = self.x.lock(); let gy = self.y.lock(); }\n\
                            fn g(&self) { let gy = self.y.lock(); let gx = self.x.lock(); } }";
        let toml = format!(
            "{TWO_CLASSES}\
             [[edge]]\nfrom = \"a.x\"\nto = \"a.y\"\nwhy = \"w\"\n\
             [[edge]]\nfrom = \"a.y\"\nto = \"a.x\"\nwhy = \"w\"\n"
        );
        let out = run(&[("rust/src/a.rs", src)], &toml);
        assert!(out.iter().any(|v| v.contains("cycle")), "{out:?}");
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        // `*x.write().unwrap() = v;` then `y.lock()` is sequential — the
        // RwLock temporary cannot outlive its statement
        let src = "struct S { x: RwLock<u8>, y: Mutex<u8> }\n\
                   impl S { fn f(&self) { *self.x.write().unwrap() = 1; let gy = self.y.lock(); } }";
        let toml = "\
[[class]]
name = \"a.x\"
file = \"rust/src/a.rs\"
recv = [\"x\"]
doc = \"d\"
[[class]]
name = \"a.y\"
file = \"rust/src/a.rs\"
recv = [\"y\"]
doc = \"d\"
";
        let out = run(&[("rust/src/a.rs", src)], toml);
        assert!(!out.iter().any(|v| v.contains("while holding")), "{out:?}");
    }

    #[test]
    fn edge_found_through_call_graph_and_guard_returning_helper() {
        // lock_x returns a MutexGuard, so the caller holds `a.x` when it
        // calls `self.touch_y()`, which locks `a.y` — cross-fn edge
        let src = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S {\n\
                     fn lock_x(&self) -> MutexGuard<'_, u8> { self.x.lock() }\n\
                     fn touch_y(&self) { let gy = self.y.lock(); }\n\
                     fn f(&self) { let gx = self.lock_x(); self.touch_y(); }\n\
                   }";
        let out = run(&[("rust/src/a.rs", src)], TWO_CLASSES);
        assert!(
            out.iter().any(|v| v.contains("acquiring `a.y` while holding `a.x`")),
            "{out:?}"
        );
    }

    #[test]
    fn non_guard_returning_callee_releases_before_returning() {
        // f calls two acquiring fns sequentially; neither returns a
        // guard, so no nesting edge exists
        let src = "struct S { x: Mutex<u8>, y: Mutex<u8> }\n\
                   impl S {\n\
                     fn touch_x(&self) { let gx = self.x.lock(); }\n\
                     fn touch_y(&self) { let gy = self.y.lock(); }\n\
                     fn f(&self) { self.touch_x(); self.touch_y(); }\n\
                   }";
        let out = run(&[("rust/src/a.rs", src)], TWO_CLASSES);
        assert!(!out.iter().any(|v| v.contains("while holding")), "{out:?}");
    }

    #[test]
    fn blocking_recv_under_guard_fires_and_allow_escapes() {
        let one_class = "\
[[class]]
name = \"a.x\"
file = \"rust/src/a.rs\"
recv = [\"x\"]
doc = \"d\"
";
        let src = "struct S { x: Mutex<Receiver<u8>> }\n\
                   impl S { fn f(&self) { let g = self.x.lock(); let v = g.recv(); } }";
        let out = run(&[("rust/src/a.rs", src)], one_class);
        assert!(
            out.iter().any(|v| v.contains("[blocking-under-lock]") && v.contains(".recv()")),
            "{out:?}"
        );
        let allowed = "struct S { x: Mutex<Receiver<u8>> }\n\
                       impl S { fn f(&self) { let g = self.x.lock();\n\
                       // lint:allow(blocking-under-lock) — single-consumer dequeue by design\n\
                       let v = g.recv(); } }";
        let out = run(&[("rust/src/a.rs", allowed)], one_class);
        assert!(out.is_empty(), "{out:?}");
        // after the guard's block closes, recv is fine
        let seq = "struct S { x: Mutex<u8>, rx: Receiver<u8> }\n\
                   impl S { fn f(&self) { { let g = self.x.lock(); } let v = self.rx.recv(); } }";
        let out = run(&[("rust/src/a.rs", seq)], one_class);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn file_io_and_range_drain_semantics() {
        let one_class = "\
[[class]]
name = \"a.x\"
file = \"rust/src/a.rs\"
recv = [\"x\"]
doc = \"d\"
";
        // file I/O under a guard fires
        let src = "struct S { x: Mutex<u8> }\n\
                   impl S { fn f(&self) { let g = self.x.lock(); fh.read_exact(&mut buf); } }";
        let out = run(&[("rust/src/a.rs", src)], one_class);
        assert!(out.iter().any(|v| v.contains("read_exact")), "{out:?}");
        // Vec::drain(range) under a guard is NOT the blocking barrier
        let src = "struct S { x: Mutex<u8> }\n\
                   impl S { fn f(&self) { let g = self.x.lock(); v.drain(0..n); } }";
        let out = run(&[("rust/src/a.rs", src)], one_class);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unclassified_lock_and_undeclared_mutex_file_fire() {
        // a `.lock()` whose (file, recv) has no class
        let src = "struct S { z: Mutex<u8> }\nimpl S { fn f(&self) { let g = self.z.lock(); } }";
        let toml = "\
[[class]]
name = \"b.q\"
file = \"rust/src/b.rs\"
recv = [\"q\"]
doc = \"d\"
";
        let srcs = [
            ("rust/src/a.rs", src),
            (
                "rust/src/b.rs",
                "struct T { q: Mutex<u8> }\nimpl T { fn f(&self) { let g = self.q.lock(); } }",
            ),
        ];
        let out = run(&srcs, toml);
        assert!(out.iter().any(|v| v.contains("unclassified mutex")), "{out:?}");
        assert!(out.iter().any(|v| v.contains("no lock-order.toml class")), "{out:?}");
    }
}
