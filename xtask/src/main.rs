//! Repo-specific static analysis: `cargo run -p xtask -- <lint|analyze>`.
//!
//! Both commands are wired into `make check` and CI, and both scan
//! `rust/src/**.rs` through the shared syntax-aware lexer (`lexer.rs`):
//! comment/string/raw-string/char-literal aware tokenization, brace-depth
//! and fn-boundary tracking, `#[cfg(test)]` items excluded.
//!
//! * `lint` — the four PR-6 rules (narrowing-cast, unsafe-budget,
//!   unwrap-ban, relaxed-ordering), ported from the old line-regex
//!   scanner onto the token stream with identical semantics. See
//!   `lint.rs`.
//! * `analyze` — five syntax-aware passes over the token stream and the
//!   crate-local call graph (`callgraph.rs`):
//!   lock-order/deadlock (`locks.rs`, checked against `lock-order.toml`),
//!   blocking-under-lock (same walk), acquire-release pairing
//!   (`ordering.rs`, checked against `ordering-pairs.toml`),
//!   ledger-billing completeness (`billing.rs`), and the metrics-registry
//!   ratchet (`metrics.rs`, checked against `metrics-registry.toml`).
//!
//! Escape hatch everywhere: a line (or one of the 6 lines above it)
//! containing `lint:allow(<rule>)` exempts that site; the comment must
//! say why. Manifests are ratchets: entries that no longer match a real
//! source site are errors, so the checked-in files always record the
//! truth. The pass catalog and manifest formats are documented in
//! docs/STATIC_ANALYSIS.md.

mod billing;
mod callgraph;
mod config;
mod lexer;
mod lint;
mod locks;
mod metrics;
mod ordering;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root = PathBuf::from(".");
    let mut cmd = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                root = PathBuf::from(args.get(i).map(String::as_str).unwrap_or("."));
            }
            c if cmd.is_none() => cmd = Some(c.to_string()),
            other => {
                eprintln!("unexpected argument: {other}");
                return ExitCode::FAILURE;
            }
        }
        i += 1;
    }
    let run = |name: &str, result: Result<Vec<String>, String>| match result {
        Ok(violations) if violations.is_empty() => {
            println!("xtask {name}: clean");
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!("xtask {name}: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask {name}: {e}");
            ExitCode::FAILURE
        }
    };
    match cmd.as_deref() {
        Some("lint") => run("lint", lint::run_lint(&root)),
        Some("analyze") => run("analyze", run_analyze(&root)),
        _ => {
            eprintln!("usage: cargo run -p xtask -- <lint|analyze> [--root <repo-root>]");
            ExitCode::FAILURE
        }
    }
}

fn read(root: &Path, name: &str) -> Result<String, String> {
    let p = root.join(name);
    std::fs::read_to_string(&p).map_err(|e| format!("{}: {e}", p.display()))
}

fn run_analyze(root: &Path) -> Result<Vec<String>, String> {
    let lock_cfg = config::parse_lock_order(&read(root, "lock-order.toml")?, "lock-order.toml")?;
    let pairs =
        config::parse_ordering_pairs(&read(root, "ordering-pairs.toml")?, "ordering-pairs.toml")?;
    let cells =
        config::parse_counts(&read(root, "metrics-registry.toml")?, "metrics-registry.toml")?;
    let files = lexer::collect_sources(root).map_err(|e| format!("scanning rust/src: {e}"))?;
    let g = callgraph::CallGraph::build(&files);
    let mut out = Vec::new();
    locks::check(&files, &g, &lock_cfg, &mut out);
    ordering::check(&files, &pairs, &mut out);
    billing::check(&files, &g, &mut out);
    metrics::check(&files, &cells, &mut out);
    Ok(out)
}

// ------------------------------------------------------------ self-test

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end: the classic lint must pass on the real tree. This is
    /// the same invocation `make lint` runs, from the workspace root.
    #[test]
    fn lint_is_clean_on_this_repo() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let violations = lint::run_lint(&root).expect("lint run failed");
        assert!(violations.is_empty(), "lint violations:\n{}", violations.join("\n"));
    }

    /// End-to-end: the four analyze passes must pass on the real tree —
    /// and because manifests are ratchets, this simultaneously proves
    /// every lock-order.toml class/edge and every ordering-pairs.toml
    /// entry corresponds to a real source site.
    #[test]
    fn analyze_is_clean_on_this_repo() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let violations = run_analyze(&root).expect("analyze run failed");
        assert!(violations.is_empty(), "analyze violations:\n{}", violations.join("\n"));
    }

    /// The real tree has no declared lock-nesting edges: every lock in
    /// the crate is leaf-ordered (docs/CONCURRENCY.md). If an [[edge]]
    /// ever appears, this test makes the author read the deadlock
    /// discussion there first.
    #[test]
    fn lock_order_manifest_declares_no_edges_today() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let cfg = config::parse_lock_order(
            &read(&root, "lock-order.toml").unwrap(),
            "lock-order.toml",
        )
        .unwrap();
        assert!(
            cfg.edges.is_empty(),
            "a lock-nesting edge was declared — update docs/CONCURRENCY.md's lock-order \
             section and this test if the leaf-only discipline is deliberately being relaxed"
        );
        assert!(!cfg.classes.is_empty());
    }
}
