//! Repo-specific lint pass (PR 6): `cargo run -p xtask -- lint`.
//!
//! Wired into `make check` and CI. Four rules, all scoped to
//! `rust/src/**.rs` *outside* `#[cfg(test)]` modules (test modules are
//! by convention the last item of a file, so scanning stops at the first
//! `#[cfg(test)]` line):
//!
//! * **narrowing-cast** — no `as usize` / `as u32` on lines doing
//!   offset/byte arithmetic outside `util/bytes.rs`. This is the PR-4
//!   mmap bug class: `(i * dim * 4) as u64` truncates before it widens;
//!   byte math must widen first (`i as u64 * dim as u64 * 4`).
//! * **unsafe-budget** — every `unsafe` must carry a `SAFETY:` (or
//!   `# Safety` doc) contract within the 10 lines above it, and per-file
//!   `unsafe` counts must exactly match `unsafe-budget.toml`. The budget
//!   is a ratchet: a count below budget is also an error ("lower the
//!   budget"), so the checked-in file always records the true count and
//!   its diffs surface every change in review.
//! * **unwrap-ban** — no `.unwrap()` / `.expect(` in `kvstore/` or
//!   `train/prefetch.rs`: I/O-facing helper threads must degrade to the
//!   failure path, not panic (a panicked writer poisons its link's locks
//!   and strands the trainer mid-drain).
//! * **relaxed-ordering** — `Ordering::Relaxed` only in files listed in
//!   `relaxed-allowlist.toml`, at no more than the recorded count. The
//!   allowlist encodes the audit of docs/CONCURRENCY.md: Relaxed is for
//!   statistics counters only, never for data visibility.
//!
//! Escape hatch: a line (or one of the 6 lines above it, for comment
//! blocks) containing `lint:allow(<rule>)` exempts that site; the
//! comment must say why.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const NARROWING: &str = "narrowing-cast";
const UNSAFE: &str = "unsafe-budget";
const UNWRAP: &str = "unwrap-ban";
const RELAXED: &str = "relaxed-ordering";

/// How far above a flagged line a `lint:allow` comment may sit.
const ALLOW_LOOKBACK: usize = 6;
/// How far above an `unsafe` a SAFETY contract may sit.
const SAFETY_LOOKBACK: usize = 10;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root = PathBuf::from(".");
    let mut cmd = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                root = PathBuf::from(args.get(i).map(String::as_str).unwrap_or("."));
            }
            c if cmd.is_none() => cmd = Some(c.to_string()),
            other => {
                eprintln!("unexpected argument: {other}");
                return ExitCode::FAILURE;
            }
        }
        i += 1;
    }
    match cmd.as_deref() {
        Some("lint") => match run_lint(&root) {
            Ok(violations) if violations.is_empty() => {
                println!("xtask lint: clean");
                ExitCode::SUCCESS
            }
            Ok(violations) => {
                for v in &violations {
                    eprintln!("{v}");
                }
                eprintln!("xtask lint: {} violation(s)", violations.len());
                ExitCode::FAILURE
            }
            Err(e) => {
                eprintln!("xtask lint: {e}");
                ExitCode::FAILURE
            }
        },
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--root <repo-root>]");
            ExitCode::FAILURE
        }
    }
}

/// One source file, pre-processed for scanning: raw lines plus their
/// comment-stripped code part, truncated at the first `#[cfg(test)]`.
struct SourceFile {
    /// repo-relative path with forward slashes
    rel: String,
    raw: Vec<String>,
    code: Vec<String>,
}

/// Strip a line comment (`//` outside a string literal). Good enough for
/// lexical scanning: tracks double-quote strings with backslash escapes;
/// does not attempt block comments or raw strings (neither is used for
/// the patterns these rules match).
fn code_part(line: &str) -> String {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1, // skip the escaped char
            b'"' => in_str = !in_str,
            b'/' if !in_str && i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                return line[..i].to_string();
            }
            _ => {}
        }
        i += 1;
    }
    line.to_string()
}

fn load_source(path: &Path, rel: String) -> std::io::Result<SourceFile> {
    let text = std::fs::read_to_string(path)?;
    let mut raw = Vec::new();
    for line in text.lines() {
        if line.trim() == "#[cfg(test)]" {
            break; // test modules are the last item of a file
        }
        raw.push(line.to_string());
    }
    let code = raw.iter().map(|l| code_part(l)).collect();
    Ok(SourceFile { rel, raw, code })
}

fn collect_sources(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let src = root.join("rust/src");
    let mut files = Vec::new();
    let mut stack = vec![src.clone()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = format!(
                    "rust/src/{}",
                    path.strip_prefix(&src)
                        .expect("path under rust/src")
                        .display()
                )
                .replace('\\', "/");
                files.push(load_source(&path, rel)?);
            }
        }
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(files)
}

/// `lint:allow(<rule>)` on the line itself or up to ALLOW_LOOKBACK lines
/// above (multi-line justification comments).
fn is_allowed(file: &SourceFile, idx: usize, rule: &str) -> bool {
    let marker = format!("lint:allow({rule})");
    let lo = idx.saturating_sub(ALLOW_LOOKBACK);
    file.raw[lo..=idx].iter().any(|l| l.contains(&marker))
}

fn violation(file: &SourceFile, idx: usize, rule: &str, msg: &str) -> String {
    format!("{}:{}: [{rule}] {msg}: {}", file.rel, idx + 1, file.raw[idx].trim())
}

// ---------------------------------------------------------------- rules

/// Markers that identify a line as offset/byte arithmetic. `offsets[` is
/// excluded: CSR offset *arrays* index by id, which is not byte math.
fn is_byte_math(code: &str) -> bool {
    code.contains("byte")
        || code.contains("* 4")
        || code.contains("*4")
        || (code.contains("offset") && !code.contains("offsets["))
}

fn check_narrowing(file: &SourceFile, out: &mut Vec<String>) {
    if file.rel.ends_with("util/bytes.rs") {
        return; // the sanctioned home of byte reinterpretation
    }
    for (i, code) in file.code.iter().enumerate() {
        let has_cast = code.contains(" as usize") || code.contains(" as u32");
        if has_cast && is_byte_math(code) && !is_allowed(file, i, NARROWING) {
            out.push(violation(
                file,
                i,
                NARROWING,
                "narrowing cast in offset/byte math (widen first: `i as u64 * dim as u64 * 4`)",
            ));
        }
    }
}

/// Word-boundary occurrences of `unsafe` in a code line.
fn count_unsafe(code: &str) -> usize {
    let b = code.as_bytes();
    let mut n = 0;
    let mut from = 0;
    while let Some(p) = code[from..].find("unsafe") {
        let start = from + p;
        let end = start + "unsafe".len();
        let pre_ok = start == 0 || !(b[start - 1] as char).is_alphanumeric() && b[start - 1] != b'_';
        let post_ok = end >= b.len() || !(b[end] as char).is_alphanumeric() && b[end] != b'_';
        if pre_ok && post_ok {
            n += 1;
        }
        from = end;
    }
    n
}

fn has_safety_contract(file: &SourceFile, idx: usize) -> bool {
    let lo = idx.saturating_sub(SAFETY_LOOKBACK);
    file.raw[lo..=idx].iter().any(|l| l.contains("SAFETY") || l.contains("# Safety"))
}

fn check_unsafe(
    file: &SourceFile,
    budget: &BTreeMap<String, usize>,
    out: &mut Vec<String>,
) -> usize {
    let mut count = 0;
    for (i, code) in file.code.iter().enumerate() {
        let n = count_unsafe(code);
        if n == 0 {
            continue;
        }
        count += n;
        if !has_safety_contract(file, i) && !is_allowed(file, i, UNSAFE) {
            out.push(violation(
                file,
                i,
                UNSAFE,
                "unsafe without a SAFETY: contract in the 10 lines above",
            ));
        }
    }
    match (count, budget.get(&file.rel)) {
        (0, None) => {}
        (n, Some(&b)) if n == b => {}
        (n, Some(&b)) if n > b => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s), budget is {b} — do not add unsafe; \
             refactor or (exceptionally) raise the budget with review",
            file.rel
        )),
        (n, Some(&b)) => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s), budget is {b} — \
             lower the budget in unsafe-budget.toml (the count may only go down)",
            file.rel
        )),
        (n, None) => out.push(format!(
            "{}: [{UNSAFE}] {n} unsafe occurrence(s) but the file is not in unsafe-budget.toml",
            file.rel
        )),
    }
    count
}

fn unwrap_ban_applies(rel: &str) -> bool {
    rel.starts_with("rust/src/kvstore/")
        || rel.starts_with("rust/src/serve/")
        || rel == "rust/src/train/prefetch.rs"
}

fn check_unwrap(file: &SourceFile, out: &mut Vec<String>) {
    if !unwrap_ban_applies(&file.rel) {
        return;
    }
    for (i, code) in file.code.iter().enumerate() {
        if (code.contains(".unwrap()") || code.contains(".expect(")) && !is_allowed(file, i, UNWRAP)
        {
            out.push(violation(
                file,
                i,
                UNWRAP,
                "unwrap/expect in I/O-facing code (return a Result or recover from poison)",
            ));
        }
    }
}

fn check_relaxed(
    file: &SourceFile,
    allow: &BTreeMap<String, usize>,
    out: &mut Vec<String>,
) -> usize {
    let mut count = 0;
    let mut first = None;
    for (i, code) in file.code.iter().enumerate() {
        let n = code.matches("Ordering::Relaxed").count();
        if n > 0 {
            if is_allowed(file, i, RELAXED) {
                continue;
            }
            count += n;
            first.get_or_insert(i);
        }
    }
    if count == 0 {
        return 0;
    }
    match allow.get(&file.rel) {
        Some(&max) if count <= max => {}
        Some(&max) => out.push(format!(
            "{}: [{RELAXED}] {count} Ordering::Relaxed site(s), allowlist permits {max} — \
             new Relaxed uses need a docs/CONCURRENCY.md audit entry first",
            file.rel
        )),
        None => out.push(violation(
            file,
            first.unwrap_or(0),
            RELAXED,
            "Ordering::Relaxed in a file absent from relaxed-allowlist.toml \
             (audit it in docs/CONCURRENCY.md, then allowlist it)",
        )),
    }
    count
}

// ----------------------------------------------------- config file I/O

/// Parse the TOML subset both config files use: comments, a `[files]`
/// section, and `"quoted/path.rs" = <integer>` entries.
fn parse_counts_toml(text: &str, origin: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut map = BTreeMap::new();
    let mut in_files = false;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with('[') {
            in_files = line == "[files]";
            continue;
        }
        if !in_files {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("{origin}:{}: expected `\"path\" = count`", ln + 1))?;
        let key = key.trim().trim_matches('"').to_string();
        let value = value.trim().split('#').next().unwrap_or("").trim();
        let count: usize = value
            .parse()
            .map_err(|_| format!("{origin}:{}: count must be an integer", ln + 1))?;
        map.insert(key, count);
    }
    Ok(map)
}

fn run_lint(root: &Path) -> Result<Vec<String>, String> {
    let budget_path = root.join("unsafe-budget.toml");
    let allow_path = root.join("relaxed-allowlist.toml");
    let budget = parse_counts_toml(
        &std::fs::read_to_string(&budget_path)
            .map_err(|e| format!("{}: {e}", budget_path.display()))?,
        "unsafe-budget.toml",
    )?;
    let allow = parse_counts_toml(
        &std::fs::read_to_string(&allow_path)
            .map_err(|e| format!("{}: {e}", allow_path.display()))?,
        "relaxed-allowlist.toml",
    )?;
    let files = collect_sources(root).map_err(|e| format!("scanning rust/src: {e}"))?;
    let mut out = Vec::new();
    let mut seen_unsafe: BTreeMap<String, usize> = BTreeMap::new();
    let mut seen_relaxed: BTreeMap<String, usize> = BTreeMap::new();
    for file in &files {
        check_narrowing(file, &mut out);
        check_unwrap(file, &mut out);
        let u = check_unsafe(file, &budget, &mut out);
        if u > 0 {
            seen_unsafe.insert(file.rel.clone(), u);
        }
        let r = check_relaxed(file, &allow, &mut out);
        if r > 0 {
            seen_relaxed.insert(file.rel.clone(), r);
        }
    }
    // stale config entries hide future regressions: flag them
    for path in budget.keys() {
        if !seen_unsafe.contains_key(path) {
            out.push(format!(
                "unsafe-budget.toml: [{UNSAFE}] stale entry {path:?} (file gone or unsafe-free) \
                 — remove it"
            ));
        }
    }
    for path in allow.keys() {
        if !seen_relaxed.contains_key(path) {
            out.push(format!(
                "relaxed-allowlist.toml: [{RELAXED}] stale entry {path:?} (file gone or \
                 Relaxed-free) — remove it"
            ));
        }
    }
    Ok(out)
}

// ------------------------------------------------------------ self-test

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(rel: &str, body: &str) -> SourceFile {
        let mut raw = Vec::new();
        for line in body.lines() {
            if line.trim() == "#[cfg(test)]" {
                break;
            }
            raw.push(line.to_string());
        }
        let code = raw.iter().map(|l| code_part(l)).collect();
        SourceFile { rel: rel.to_string(), raw, code }
    }

    #[test]
    fn code_part_strips_comments_not_strings() {
        assert_eq!(code_part("let x = 1; // as usize * 4"), "let x = 1; ");
        assert_eq!(code_part(r#"let s = "https://a"; y"#), r#"let s = "https://a"; y"#);
        assert_eq!(code_part("// pure comment"), "");
    }

    #[test]
    fn narrowing_flags_seeded_violation() {
        let f = fixture("rust/src/store/x.rs", "let off = (i * dim * 4) as usize;\n");
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("narrowing-cast"));
    }

    #[test]
    fn narrowing_respects_allow_and_scope() {
        // annotated site passes
        let f = fixture(
            "rust/src/store/x.rs",
            "// lint:allow(narrowing-cast) — bounded by the clamp below\n\
             let off = (i * dim * 4) as usize;\n",
        );
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // util/bytes.rs is exempt wholesale
        let f = fixture("rust/src/util/bytes.rs", "let off = (i * dim * 4) as usize;\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // id-space casts (no byte-math marker) pass
        let f = fixture("rust/src/kg/x.rs", "let id = v as usize;\nlet n = k.len() as u32;\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // CSR offset arrays are id indexing, not byte math
        let f = fixture("rust/src/kg/x.rs", "let lo = self.offsets[v as usize] as usize;\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn narrowing_ignores_test_modules_and_comments() {
        let f = fixture(
            "rust/src/store/x.rs",
            "fn ok() {}\n#[cfg(test)]\nmod tests { let off = (i * 4) as usize; }\n",
        );
        let mut out = Vec::new();
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        let f = fixture("rust/src/store/x.rs", "// old code: let off = (i * 4) as usize;\n");
        check_narrowing(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unsafe_requires_safety_contract_and_budget() {
        let mut budget = BTreeMap::new();
        budget.insert("rust/src/store/x.rs".to_string(), 1);
        // contract present, budget exact: clean
        let f = fixture(
            "rust/src/store/x.rs",
            "// SAFETY: the slice outlives the call\nlet s = unsafe { mk() };\n",
        );
        let mut out = Vec::new();
        assert_eq!(check_unsafe(&f, &budget, &mut out), 1);
        assert!(out.is_empty(), "{out:?}");
        // no contract: violation
        let f = fixture("rust/src/store/x.rs", "let s = unsafe { mk() };\n");
        check_unsafe(&f, &budget, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("SAFETY"));
    }

    #[test]
    fn unsafe_budget_is_a_ratchet() {
        let mut out = Vec::new();
        let mut budget = BTreeMap::new();
        budget.insert("rust/src/store/x.rs".to_string(), 2);
        let over = "// SAFETY: a\nunsafe { a() };\n// SAFETY: b\nunsafe { b() };\n\
                    // SAFETY: c\nunsafe { c() };\n";
        check_unsafe(&fixture("rust/src/store/x.rs", over), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("budget is 2")), "{out:?}");
        out.clear();
        // under budget is ALSO an error: the count may only go down
        let under = "// SAFETY: a\nunsafe { a() };\n";
        check_unsafe(&fixture("rust/src/store/x.rs", under), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("lower the budget")), "{out:?}");
        out.clear();
        // unsafe in a file the budget has never heard of
        check_unsafe(&fixture("rust/src/store/y.rs", under), &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("not in unsafe-budget.toml")), "{out:?}");
    }

    #[test]
    fn unsafe_in_kernels_is_budgeted_like_everywhere_else() {
        // The fused kernels (rust/src/models/kernels.rs) are written in
        // autovectorization-friendly safe Rust on purpose — the file has
        // no unsafe-budget.toml entry, so this pins that sneaking a
        // `unsafe` intrinsic block into them fails the lint until the
        // budget is consciously amended (docs/KERNELS.md).
        let budget_path =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("unsafe-budget.toml");
        let budget = parse_counts_toml(
            &std::fs::read_to_string(budget_path).expect("unsafe-budget.toml readable"),
            "unsafe-budget.toml",
        )
        .expect("unsafe-budget.toml parses");
        assert!(
            !budget.contains_key("rust/src/models/kernels.rs"),
            "kernels.rs grew an unsafe budget entry — update this test \
             and docs/KERNELS.md if that was deliberate"
        );
        let mut out = Vec::new();
        let f = fixture(
            "rust/src/models/kernels.rs",
            "// SAFETY: lanes are in bounds\nlet v = unsafe { load(ptr) };\n",
        );
        check_unsafe(&f, &budget, &mut out);
        assert!(out.iter().any(|v| v.contains("not in unsafe-budget.toml")), "{out:?}");
    }

    #[test]
    fn unsafe_token_matching_is_word_bounded() {
        assert_eq!(count_unsafe("unsafe fn f() { unsafe { g() } }"), 2);
        assert_eq!(count_unsafe("let unsafety = 1; not_unsafe()"), 0);
    }

    #[test]
    fn unwrap_ban_scoped_to_kvstore_and_prefetch() {
        let mut out = Vec::new();
        let body = "let v = rx.recv().unwrap();\nlet w = tx.send(x).expect(\"send\");\n";
        check_unwrap(&fixture("rust/src/kvstore/comm.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        check_unwrap(&fixture("rust/src/train/prefetch.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        // the serving request loop is I/O-facing helper-thread code too
        check_unwrap(&fixture("rust/src/serve/server.rs", body), &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        out.clear();
        // other modules are out of scope
        check_unwrap(&fixture("rust/src/store/cache.rs", body), &mut out);
        assert!(out.is_empty(), "{out:?}");
        // annotated designed-panic passes
        let annotated = "// lint:allow(unwrap-ban) — startup path, infallible by construction\n\
                         let v = init().expect(\"cannot fail\");\n";
        check_unwrap(&fixture("rust/src/kvstore/server.rs", annotated), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn relaxed_requires_allowlist_and_count() {
        let mut allow = BTreeMap::new();
        allow.insert("rust/src/store/cache.rs".to_string(), 2);
        let mut out = Vec::new();
        let two = "hits.fetch_add(1, Ordering::Relaxed);\nmisses.load(Ordering::Relaxed);\n";
        assert_eq!(check_relaxed(&fixture("rust/src/store/cache.rs", two), &allow, &mut out), 2);
        assert!(out.is_empty(), "{out:?}");
        // one more than the allowlist records
        let three = format!("{two}evictions.load(Ordering::Relaxed);\n");
        check_relaxed(&fixture("rust/src/store/cache.rs", &three), &allow, &mut out);
        assert!(out.iter().any(|v| v.contains("allowlist permits 2")), "{out:?}");
        out.clear();
        // un-allowlisted file
        check_relaxed(&fixture("rust/src/train/new.rs", two), &allow, &mut out);
        assert!(out.iter().any(|v| v.contains("absent from relaxed-allowlist")), "{out:?}");
    }

    #[test]
    fn counts_toml_subset_parses() {
        let text = "# comment\n[files]\n\"rust/src/a.rs\" = 3\n\"rust/src/b.rs\" = 0 # note\n";
        let m = parse_counts_toml(text, "t").unwrap();
        assert_eq!(m.get("rust/src/a.rs"), Some(&3));
        assert_eq!(m.get("rust/src/b.rs"), Some(&0));
        assert!(parse_counts_toml("[files]\nbad line\n", "t").is_err());
        assert!(parse_counts_toml("[files]\n\"a\" = x\n", "t").is_err());
    }

    /// End-to-end: the lint must pass on the real tree. This is the same
    /// invocation `make lint` runs, executed from the workspace root.
    #[test]
    fn lint_is_clean_on_this_repo() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let violations = run_lint(&root).expect("lint run failed");
        assert!(violations.is_empty(), "lint violations:\n{}", violations.join("\n"));
    }
}
