//! Metrics-registry pass.
//!
//! PR 10 moved every ad-hoc statistics counter (`CachedStore` hit/miss,
//! `NetLedger` byte tallies, serve served/error, ...) into the
//! `obs::metrics` registry, where it gets a name, shows up in `Report`
//! snapshots, and is summed across instances. A raw `AtomicU64` outside
//! `rust/src/obs/` is therefore one of two things: a *synchronization*
//! cell (a stamp or ack counter whose Release/Acquire protocol is the
//! point — those are audited by the ordering pass) or a regression back
//! to an invisible ad-hoc stat. This pass makes the distinction explicit:
//! every `AtomicU64` token outside the exempt files must carry
//! `lint:allow(metrics-registry)` naming its protocol, and the per-file
//! site counts must match `metrics-registry.toml` exactly — the same
//! two-sided ratchet as `unsafe-budget.toml`, so a new raw atomic cannot
//! land without both an inline justification and a manifest diff.
//!
//! Exempt: `rust/src/obs/` (the registry's own cells) and
//! `rust/src/util/sync.rs` (the loom shim wrapping the type itself).
//! `use` imports are declarations, not sites.

use crate::lexer::{FileLex, Kind, Tok};
use std::collections::BTreeMap;

pub const METRICS: &str = "metrics-registry";

fn exempt(rel: &str) -> bool {
    rel.starts_with("rust/src/obs/") || rel == "rust/src/util/sync.rs"
}

/// Is token `i` part of a `use` item? Walk back to the start of the
/// enclosing statement (the previous `;`); if a `use` keyword appears
/// first, this is an import, not a usage site. Brace tokens are skipped
/// so `use a::{X, Y};` groups resolve correctly.
fn in_use_item(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        if t.is(";") {
            return false;
        }
        if t.is_id("use") {
            return true;
        }
    }
    false
}

pub fn check(files: &[FileLex], counts: &BTreeMap<String, usize>, out: &mut Vec<String>) {
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for f in files {
        if exempt(&f.rel) {
            continue;
        }
        let mut n = 0usize;
        for (i, t) in f.toks.iter().enumerate() {
            if t.kind != Kind::Id || t.text != "AtomicU64" || in_use_item(&f.toks, i) {
                continue;
            }
            n += 1;
            if !f.has_allow(t.line, METRICS) {
                out.push(format!(
                    "{}:{}: [{METRICS}] raw AtomicU64 outside obs::metrics — an ad-hoc stat \
                     belongs in the registry (`obs::metrics::global().counter(\"...\")`); a \
                     true synchronization cell needs `lint:allow(metrics-registry)` naming \
                     its protocol",
                    f.rel, t.line
                ));
            }
        }
        if n > 0 {
            seen.insert(f.rel.clone(), n);
        }
        match (n, counts.get(&f.rel)) {
            (0, None) => {}
            (n, Some(&b)) if n == b => {}
            (n, Some(&b)) if n > b => out.push(format!(
                "{}: [{METRICS}] {n} raw AtomicU64 site(s), metrics-registry.toml records {b} \
                 — new cells go through the obs::metrics registry; a genuine synchronization \
                 cell raises the count with review",
                f.rel
            )),
            (n, Some(&b)) => out.push(format!(
                "{}: [{METRICS}] {n} raw AtomicU64 site(s), metrics-registry.toml records {b} \
                 — lower the manifest count (it may only go down)",
                f.rel
            )),
            (n, None) => out.push(format!(
                "{}: [{METRICS}] {n} raw AtomicU64 site(s) but the file is not in \
                 metrics-registry.toml",
                f.rel
            )),
        }
    }
    for path in counts.keys() {
        if !seen.contains_key(path) {
            out.push(format!(
                "metrics-registry.toml: [{METRICS}] stale entry {path:?} (file gone or \
                 AtomicU64-free) — remove it"
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_counts;

    fn run(srcs: &[(&str, &str)], toml: &str) -> Vec<String> {
        let files: Vec<FileLex> =
            srcs.iter().map(|(rel, s)| FileLex::from_source(rel, s)).collect();
        let counts = parse_counts(toml, "metrics-registry.toml").expect("fixture parses");
        let mut out = Vec::new();
        check(&files, &counts, &mut out);
        out
    }

    const ONE: &str = "[files]\n\"rust/src/a.rs\" = 1\n";

    #[test]
    fn annotated_and_counted_site_is_clean() {
        let src = "// lint:allow(metrics-registry) — applied-stamp Release/Acquire protocol\n\
                   static STAMP: AtomicU64 = AtomicU64::new(0);\n";
        // two tokens on one line: the type position and the constructor
        let toml = "[files]\n\"rust/src/a.rs\" = 2\n";
        let out = run(&[("rust/src/a.rs", src)], toml);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unannotated_site_fires_even_when_counted() {
        let src = "fn f() { let c = Arc::new(AtomicU64::new(0)); }\n";
        let out = run(&[("rust/src/a.rs", src)], ONE);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("obs::metrics"), "{out:?}");
    }

    #[test]
    fn count_is_an_exact_two_sided_ratchet() {
        let annotated = "// lint:allow(metrics-registry) — ack protocol\n\
                         fn f(acked: Arc<AtomicU64>) {}\n";
        // more sites than recorded
        let doubled = format!("{annotated}// lint:allow(metrics-registry) — second cell\n\
                               fn g(acked: Arc<AtomicU64>) {{}}\n");
        let out = run(&[("rust/src/a.rs", &doubled)], ONE);
        assert!(out.iter().any(|v| v.contains("records 1")), "{out:?}");
        // fewer sites than recorded: the manifest must ratchet down
        let toml = "[files]\n\"rust/src/a.rs\" = 3\n";
        let out = run(&[("rust/src/a.rs", annotated)], toml);
        assert!(out.iter().any(|v| v.contains("lower the manifest")), "{out:?}");
        // a file the manifest has never heard of
        let out = run(&[("rust/src/b.rs", annotated)], ONE);
        assert!(out.iter().any(|v| v.contains("not in metrics-registry.toml")), "{out:?}");
        assert!(out.iter().any(|v| v.contains("stale entry")), "{out:?}");
    }

    #[test]
    fn use_imports_obs_and_shim_are_exempt() {
        let src = "use crate::util::sync::atomic::{AtomicU64, Ordering};\nfn f() {}\n";
        let out = run(&[("rust/src/a.rs", src)], "");
        assert!(out.is_empty(), "{out:?}");
        let raw = "fn f() { let c = AtomicU64::new(0); }\n";
        let out = run(&[("rust/src/obs/metrics.rs", raw), ("rust/src/util/sync.rs", raw)], "");
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn test_modules_are_out_of_scope() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n\
                   fn t() { let c = AtomicU64::new(0); }\n}\n";
        let out = run(&[("rust/src/a.rs", src)], "");
        assert!(out.is_empty(), "{out:?}");
    }
}
