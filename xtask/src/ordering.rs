//! Acquire-release pairing pass.
//!
//! A `Release` store is only a synchronization point if some `Acquire`
//! load observes it — and vice versa. Editing one side (or deleting it
//! in a refactor) silently downgrades the other side to an expensive
//! no-op. This pass forces every non-`Relaxed` atomic ordering site to
//! be registered in `ordering-pairs.toml`, where each `[pair.<name>]`
//! lists the Release sites and the Acquire sites that observe them, so
//! neither side can change alone without a manifest diff in review.
//!
//! Site keys are `"<file>::<Type::fn>"` (the enclosing function) — the
//! granularity that survives line churn but still moves when code moves.
//! A fn with two sites on one side lists its key twice; counts must
//! match exactly (stale or missing entries are errors, same ratchet
//! discipline as `unsafe-budget.toml`). `AcqRel`/`SeqCst` have no
//! two-sided representation here and are banned outright — this crate's
//! protocols are all store-Release/load-Acquire (fetch_add(Release) on
//! counters included); a genuine need would extend the manifest format
//! first.

use crate::config::OrderingPair;
use crate::lexer::{FileLex, Kind};
use std::collections::BTreeMap;

pub const ORDERING: &str = "ordering-pairs";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    Release,
    Acquire,
}

/// Enumerate non-Relaxed ordering sites: `<*Ordering>::(Acquire|Release|
/// AcqRel|SeqCst)`. The suffix match on the path ident keeps re-exported
/// aliases (`StdOrdering`) visible, mirroring the Relaxed lint.
fn sites(file: &FileLex) -> Vec<(usize, &'static str)> {
    let toks = &file.toks;
    let mut out = Vec::new();
    for i in 3..toks.len() {
        if toks[i].kind != Kind::Id {
            continue;
        }
        let which = match toks[i].text.as_str() {
            "Acquire" => "Acquire",
            "Release" => "Release",
            "AcqRel" => "AcqRel",
            "SeqCst" => "SeqCst",
            _ => continue,
        };
        if toks[i - 1].is(":")
            && toks[i - 2].is(":")
            && toks[i - 3].kind == Kind::Id
            && toks[i - 3].text.ends_with("Ordering")
        {
            out.push((i, which));
        }
    }
    out
}

pub fn check(files: &[FileLex], pairs: &[OrderingPair], out: &mut Vec<String>) {
    // expected multiset per side: site key -> count
    let mut expected: BTreeMap<(Side, String), usize> = BTreeMap::new();
    for p in pairs {
        for k in &p.release {
            *expected.entry((Side::Release, k.clone())).or_default() += 1;
        }
        for k in &p.acquire {
            *expected.entry((Side::Acquire, k.clone())).or_default() += 1;
        }
    }
    let mut found: BTreeMap<(Side, String), usize> = BTreeMap::new();
    for f in files {
        for (i, which) in sites(f) {
            let line = f.toks[i].line;
            if f.has_allow(line, ORDERING) {
                continue;
            }
            let side = match which {
                "Release" => Side::Release,
                "Acquire" => Side::Acquire,
                other => {
                    out.push(format!(
                        "{}:{line}: [{ORDERING}] Ordering::{other} — this crate's protocols \
                         are store-Release/load-Acquire only; if {other} is truly needed, \
                         extend ordering-pairs.toml to model it first",
                        f.rel
                    ));
                    continue;
                }
            };
            let Some(key) = f.site_key(i) else {
                out.push(format!(
                    "{}:{line}: [{ORDERING}] {which} ordering outside any fn — cannot \
                     attribute it to a pair",
                    f.rel
                ));
                continue;
            };
            let n = found.entry((side, key.clone())).or_default();
            *n += 1;
            let budget = expected.get(&(side, key.clone())).copied().unwrap_or(0);
            if *n > budget {
                let (side_name, other) = if side == Side::Release {
                    ("Release store", "Acquire load(s)")
                } else {
                    ("Acquire load", "Release store(s)")
                };
                out.push(format!(
                    "{}:{line}: [{ORDERING}] {side_name} in `{key}` is not registered in \
                     ordering-pairs.toml — add it to the pair naming the {other} it \
                     synchronizes with (an unpaired side is an orphan)",
                    f.rel
                ));
            }
        }
    }
    // stale manifest entries: registered sites that no longer exist
    for ((side, key), &want) in &expected {
        let have = found.get(&(*side, key.clone())).copied().unwrap_or(0);
        if have < want {
            let side_name = if *side == Side::Release { "release" } else { "acquire" };
            out.push(format!(
                "ordering-pairs.toml: [{ORDERING}] stale {side_name} entry `{key}` \
                 ({want} registered, {have} in source) — the paired protocol changed; \
                 update the pair and re-audit its other side in docs/CONCURRENCY.md"
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_ordering_pairs;

    fn run(srcs: &[(&str, &str)], toml: &str) -> Vec<String> {
        let files: Vec<FileLex> =
            srcs.iter().map(|(rel, s)| FileLex::from_source(rel, s)).collect();
        let pairs = parse_ordering_pairs(toml, "ordering-pairs.toml").expect("fixture parses");
        let mut out = Vec::new();
        check(&files, &pairs, &mut out);
        out
    }

    const PAIRED: &str = "\
[pair.stamp]
doc = \"d\"
release = [\"rust/src/a.rs::W::publish\"]
acquire = [\"rust/src/a.rs::W::observe\"]
";

    #[test]
    fn registered_pair_is_clean() {
        let src = "impl W {\n\
                   fn publish(&self) { self.s.store(1, Ordering::Release); }\n\
                   fn observe(&self) -> u64 { self.s.load(Ordering::Acquire) }\n\
                   }";
        let out = run(&[("rust/src/a.rs", src)], PAIRED);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn orphan_release_store_fires() {
        let src = "impl W {\n\
                   fn publish(&self) { self.s.store(1, Ordering::Release); }\n\
                   fn observe(&self) -> u64 { self.s.load(Ordering::Acquire) }\n\
                   fn sneak(&self) { self.t.store(2, Ordering::Release); }\n\
                   }";
        let out = run(&[("rust/src/a.rs", src)], PAIRED);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("Release store in `rust/src/a.rs::W::sneak`"), "{out:?}");
        assert!(out[0].contains("orphan"), "{out:?}");
    }

    #[test]
    fn deleting_one_side_is_a_stale_entry() {
        // the Acquire side was refactored away: its manifest entry goes
        // stale, so the dangling Release cannot survive review silently
        let src = "impl W { fn publish(&self) { self.s.store(1, Ordering::Release); } }";
        let out = run(&[("rust/src/a.rs", src)], PAIRED);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("stale acquire entry"), "{out:?}");
    }

    #[test]
    fn counts_are_exact_per_fn() {
        // two Acquire sites in one fn need the key listed twice
        let src = "impl W {\n\
                   fn publish(&self) { self.s.store(1, Ordering::Release); }\n\
                   fn observe(&self) -> u64 { self.s.load(Ordering::Acquire) + self.s.load(Ordering::Acquire) }\n\
                   }";
        let out = run(&[("rust/src/a.rs", src)], PAIRED);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("not registered"), "{out:?}");
        let doubled = "\
[pair.stamp]
doc = \"d\"
release = [\"rust/src/a.rs::W::publish\"]
acquire = [\"rust/src/a.rs::W::observe\", \"rust/src/a.rs::W::observe\"]
";
        let out = run(&[("rust/src/a.rs", src)], doubled);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn seqcst_is_banned_and_relaxed_ignored() {
        let src = "impl W { fn publish(&self) { self.s.store(1, Ordering::SeqCst); \
                   self.c.fetch_add(1, Ordering::Relaxed); } }";
        let out = run(&[("rust/src/a.rs", src)], "");
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("SeqCst"), "{out:?}");
    }

    #[test]
    fn fetch_add_release_counts_as_release() {
        let toml = "\
[pair.ctr]
doc = \"d\"
release = [\"rust/src/a.rs::bump\"]
acquire = [\"rust/src/a.rs::read_total\"]
";
        let src = "fn bump(c: &AtomicU64) { c.fetch_add(1, Ordering::Release); }\n\
                   fn read_total(c: &AtomicU64) -> u64 { c.load(Ordering::Acquire) }";
        let out = run(&[("rust/src/a.rs", src)], toml);
        assert!(out.is_empty(), "{out:?}");
    }
}
